//! Concurrent-serving integration: response routing under duplicate client
//! ids across (and within) connections, multi-consumer batcher draining,
//! and prediction-cache behaviour over repeated epochs. Runs on the
//! default native backend — no artifacts required (CI gates on this).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use thinkalloc::config::{AllocPolicy, Config};
use thinkalloc::jsonio::Json;
use thinkalloc::metrics::Registry;
use thinkalloc::prng::Pcg64;
use thinkalloc::runtime::Engine;
use thinkalloc::serving::batcher::Batcher;
use thinkalloc::serving::scheduler::Scheduler;
use thinkalloc::serving::Request;
use thinkalloc::server::{Client, Server};
use thinkalloc::workload;

/// Two drainer threads over one batcher: every submitted request is
/// delivered to exactly one drainer — nothing lost, nothing duplicated.
#[test]
fn batcher_two_drainers_no_loss_no_duplication() {
    const N: u64 = 200;
    let b = Arc::new(Batcher::new(8, Duration::from_millis(20)));
    let seen = Arc::new(Mutex::new(Vec::<u64>::new()));

    let drainers: Vec<_> = (0..2)
        .map(|_| {
            let b = b.clone();
            let seen = seen.clone();
            std::thread::spawn(move || {
                while let Some(epoch) = b.next_epoch() {
                    let mut s = seen.lock().unwrap();
                    s.extend(epoch.iter().map(|r| r.id));
                }
            })
        })
        .collect();

    let producers: Vec<_> = (0..2)
        .map(|p| {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..N / 2 {
                    assert!(b.submit(Request::new(p * (N / 2) + i, "ADD 1 2", "code")));
                    if i % 16 == 0 {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    b.close();
    for d in drainers {
        d.join().unwrap();
    }

    let mut ids = Arc::try_unwrap(seen).unwrap().into_inner().unwrap();
    assert_eq!(ids.len(), N as usize, "lost or duplicated requests");
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), N as usize, "duplicated request ids");
    assert_eq!(*ids.first().unwrap(), 0);
    assert_eq!(*ids.last().unwrap(), N - 1);
}

/// Two connections reuse the same client id (and one pipelines a duplicate
/// id); each must receive exactly its own responses. The decode procedure
/// is the discriminator: client A pins "adaptive", client B pins "route" —
/// a misrouted response carries the wrong procedure stamp.
#[test]
fn duplicate_client_ids_route_to_their_own_connection() {
    let mut cfg = Config::default();
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.server.batch_queries = 4;
    cfg.server.max_wait_ms = 20;
    cfg.server.workers = 2; // exercise the shard pool, not just one drainer
    cfg.validate().unwrap();

    let server = Server::new(cfg, Arc::new(Registry::default()));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = server.clone();
    let handle = std::thread::spawn(move || srv.run(|a| tx.send(a).unwrap()));
    let addr = rx.recv().unwrap();

    let mut a = Client::connect(&addr).unwrap();
    let mut b = Client::connect(&addr).unwrap();
    // fail fast instead of hanging if a response is misdelivered
    a.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(120))).unwrap();

    // same client id 7 everywhere; A additionally pipelines a duplicate
    a.request_with_procedure(7, "ADD 1 2", "code", "adaptive").unwrap();
    a.request_with_procedure(7, "ADD 2 3", "code", "adaptive").unwrap();
    b.request_with_procedure(7, "ADD 9 9", "code", "route").unwrap();
    b.request_with_procedure(7, "REV xy", "code", "route").unwrap();

    for _ in 0..2 {
        let resp = a.read_response().expect("client A response");
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(7.0));
        assert_eq!(
            resp.get("procedure").and_then(Json::as_str),
            Some("adaptive"),
            "client A received a response routed for client B: {resp:?}"
        );
    }
    for _ in 0..2 {
        let resp = b.read_response().expect("client B response");
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(7.0));
        assert_eq!(
            resp.get("procedure").and_then(Json::as_str),
            Some("route"),
            "client B received a response routed for client A: {resp:?}"
        );
    }

    // metrics round-trip still works through the escaped command path
    let metrics = a.command("metrics").unwrap();
    assert!(metrics.get("counter.serving.queries").is_some());
    a.command("shutdown").unwrap();
    let _ = handle.join();
}

/// Stress: four clients hammer the workers=2 pool concurrently, interleaved
/// over mixed domains; every client gets back exactly its own id set.
#[test]
fn multi_client_stress_each_client_gets_its_own_responses() {
    const CLIENTS: u64 = 4;
    const PER_CLIENT: u64 = 8;
    let mut cfg = Config::default();
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.server.batch_queries = 8;
    cfg.server.max_wait_ms = 10;
    cfg.server.workers = 2;
    cfg.validate().unwrap();

    let server = Server::new(cfg, Arc::new(Registry::default()));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = server.clone();
    let handle = std::thread::spawn(move || srv.run(|a| tx.send(a).unwrap()));
    let addr = rx.recv().unwrap();

    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                client
                    .set_read_timeout(Some(Duration::from_secs(120)))
                    .unwrap();
                let domains = ["code", "math", "chat"];
                for i in 0..PER_CLIENT {
                    let id = c * 100 + i;
                    let text = match domains[(i % 3) as usize] {
                        "chat" => format!("CHAT hello {c} {i}"),
                        _ => format!("ADD {} {}", c + 1, i + 1),
                    };
                    client
                        .request(id, &text, domains[(i % 3) as usize])
                        .unwrap();
                }
                let mut got = std::collections::BTreeSet::new();
                for _ in 0..PER_CLIENT {
                    let resp = client.read_response().expect("response");
                    let id = resp.get("id").and_then(Json::as_f64).unwrap() as u64;
                    assert!(
                        (c * 100..c * 100 + PER_CLIENT).contains(&id),
                        "client {c} received foreign id {id}"
                    );
                    assert!(got.insert(id), "client {c} received id {id} twice");
                }
                assert_eq!(got.len(), PER_CLIENT as usize);
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    let mut c = Client::connect(&addr).unwrap();
    c.command("shutdown").unwrap();
    let _ = handle.join();
}

/// Client ids above 2^53 round-trip exactly. The old path parsed the id
/// through f64 (`as_f64() as u64`), which silently corrupted large ids:
/// 2^53 + 1 came back as 2^53. Ids are now parsed and echoed as exact
/// integers.
#[test]
fn huge_integer_client_ids_echo_exactly() {
    let mut cfg = Config::default();
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.server.batch_queries = 2;
    cfg.server.max_wait_ms = 10;
    cfg.validate().unwrap();

    let server = Server::new(cfg, Arc::new(Registry::default()));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = server.clone();
    let handle = std::thread::spawn(move || srv.run(|a| tx.send(a).unwrap()));
    let addr = rx.recv().unwrap();

    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();

    // 2^53 + 1: the first integer an f64 cannot represent
    let id_a: u64 = (1 << 53) + 1;
    // well above 2^60: corrupted by hundreds under f64 rounding
    let id_b: u64 = (1 << 60) + 12345;
    c.request(id_a, "ADD 1 2", "code").unwrap();
    c.request(id_b, "ADD 3 4", "code").unwrap();

    let mut got = std::collections::BTreeSet::new();
    for _ in 0..2 {
        let resp = c.read_response().unwrap();
        let id = resp.get("id").and_then(Json::as_i64).expect("exact id");
        got.insert(id as u64);
    }
    assert_eq!(
        got.into_iter().collect::<Vec<_>>(),
        vec![id_a, id_b],
        "ids must echo bit-exactly, not f64-rounded"
    );

    c.command("shutdown").unwrap();
    let _ = handle.join();
}

/// Non-integral, negative, and ≥ 2^63 ids are rejected with a structured
/// error line — not silently truncated or wrapped — and the connection
/// stays usable afterwards.
#[test]
fn malformed_client_ids_are_rejected_not_corrupted() {
    let mut cfg = Config::default();
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.server.batch_queries = 1;
    cfg.server.max_wait_ms = 5;
    cfg.validate().unwrap();

    let server = Server::new(cfg, Arc::new(Registry::default()));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = server.clone();
    let handle = std::thread::spawn(move || srv.run(|a| tx.send(a).unwrap()));
    let addr = rx.recv().unwrap();

    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();

    // fractional, negative (used to wrap to a huge u64), and 2^63
    // (outside the exact-integer range) must all draw an error line
    for bad in [
        r#"{"id": 1.5, "text": "ADD 1 2", "domain": "code"}"#,
        r#"{"id": -3, "text": "ADD 1 2", "domain": "code"}"#,
        r#"{"id": 9223372036854775808, "text": "ADD 1 2", "domain": "code"}"#,
    ] {
        c.write_raw(bad).unwrap();
        let resp = c.read_response().unwrap();
        let err = resp.get("error").and_then(Json::as_str).unwrap_or_else(|| {
            panic!("expected an error line for {bad}, got {resp:?}")
        });
        assert!(err.contains("invalid id"), "unexpected error text: {err}");
    }

    // the connection survives rejected requests
    c.request(42, "ADD 1 2", "code").unwrap();
    let resp = c.read_response().unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_i64), Some(42));

    c.command("shutdown").unwrap();
    let _ = handle.join();
}

/// Repeating an epoch hits the prediction cache: the second pass skips the
/// probe call for every query and reports identical predictions.
#[test]
fn predict_cache_hits_on_repeated_epoch() {
    let mut cfg = Config::default();
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.predict_cache_capacity = 1024;

    let metrics = Arc::new(Registry::default());
    let engine = Engine::load_all(&cfg.runtime).unwrap();
    let scheduler = Scheduler::new(engine, cfg, metrics.clone());
    let mut rng = Pcg64::new(33);
    let batch: Vec<Request> = workload::gen_mixed_dataset(&["code", "chat"], 24, 77)
        .into_iter()
        .enumerate()
        .map(|(i, q)| Request::new(i as u64, q.text, q.domain))
        .collect();

    let distinct = batch
        .iter()
        .map(|r| (r.domain.clone(), r.text.clone()))
        .collect::<std::collections::BTreeSet<_>>()
        .len();

    let first = scheduler
        .serve_epoch(&batch, &mut rng, scheduler.effective_budget())
        .unwrap();
    let miss_after_first = metrics.counter("serving.predict_cache.miss").get();
    assert_eq!(metrics.counter("serving.predict_cache.hit").get(), 0);
    assert_eq!(miss_after_first, 24, "cold epoch must probe every query");
    assert_eq!(scheduler.shared().predict_cache_len(), distinct);

    let second = scheduler
        .serve_epoch(&batch, &mut rng, scheduler.effective_budget())
        .unwrap();
    assert_eq!(
        metrics.counter("serving.predict_cache.miss").get(),
        miss_after_first,
        "warm epoch should not probe"
    );
    assert_eq!(metrics.counter("serving.predict_cache.hit").get(), 24);
    // cached predictions are bit-identical to the probe's output
    for (f, s) in first.iter().zip(&second) {
        assert_eq!(f.predicted, s.predicted, "id {}", f.id);
        assert_eq!(f.budget, s.budget, "id {}", f.id);
    }
}
