//! Cold-vs-warm parity for the serving prefix cache: enabling the cache
//! must never change a single served byte, at any temperature, in either
//! decode mode, at any pool width — it may only change how much prefill
//! work the engine performs. Pinned here:
//!
//! * **bit parity, single worker** — a multi-turn session trace served
//!   with the cache on produces field-identical responses to the cache-off
//!   run, across `decode_mode = wave | continuous` and temperature 0 and 1
//!   (per-job seed streams mean the cache adds zero rng draws);
//! * **bit parity, pool** — the same trace through a `ShardPool` at
//!   `workers = 1 | 2`, temperature 0 (multi-worker epoch assignment is
//!   racy, so stochastic multi-worker runs are not comparable for reasons
//!   unrelated to the cache);
//! * **eviction under pressure** — a byte-starved cache that constantly
//!   evicts (and re-fills evicted prefixes on later turns) still serves
//!   bit-identical responses, while reporting nonzero evictions.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use thinkalloc::config::{AllocPolicy, Config, DecodeMode};
use thinkalloc::metrics::Registry;
use thinkalloc::prng::Pcg64;
use thinkalloc::runtime::Engine;
use thinkalloc::serving::batcher::Batcher;
use thinkalloc::serving::scheduler::{Scheduler, SchedulerShared};
use thinkalloc::serving::shard::{EpochSink, ShardPool};
use thinkalloc::serving::{Request, Response};
use thinkalloc::workload::sessions;

fn cache_config(mode: DecodeMode, temperature: f64, cache: bool) -> Config {
    let mut cfg = Config::default(); // native backend
    cfg.runtime.decode_mode = mode;
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.batch_queries = 16;
    cfg.server.temperature = temperature;
    cfg.prefix_cache.enabled = cache;
    cfg.validate().unwrap();
    cfg
}

/// One request batch per session turn: turn `t + 1`'s prompts extend turn
/// `t`'s transcripts, the shape that produces warm prefix hits.
fn session_turns() -> Vec<Vec<Request>> {
    let sessions = sessions::gen_sessions(4, 3, 2, 0x5E55);
    (0..3)
        .map(|t| {
            sessions
                .iter()
                .enumerate()
                .map(|(s, sess)| {
                    let mut r =
                        Request::new((t * 100 + s) as u64, sess.turns[t].clone(), "chat");
                    r.session = Some(sess.id);
                    r
                })
                .collect()
        })
        .collect()
}

/// Everything a response says except wall-clock latency.
fn fingerprint(r: &Response) -> (u64, String, bool, usize, u64, u32, String) {
    (
        r.id,
        r.response.clone(),
        r.ok,
        r.budget,
        r.predicted.to_bits(),
        r.reward.to_bits(),
        format!("{:?}", r.procedure),
    )
}

/// Serve each turn as its own epoch on one scheduler (the cache lives in
/// `SchedulerShared`, so it persists across epochs exactly as it does on a
/// long-lived shard worker).
fn serve_turns(
    cfg: Config,
    turns: &[Vec<Request>],
) -> (Vec<Vec<(u64, String, bool, usize, u64, u32, String)>>, Arc<Registry>) {
    let metrics = Arc::new(Registry::default());
    let engine = Engine::load_all(&cfg.runtime).unwrap();
    let scheduler = Scheduler::new(engine, cfg, metrics.clone());
    let mut rng = Pcg64::new(0x5E7E);
    let out = turns
        .iter()
        .map(|reqs| {
            scheduler
                .serve_epoch(reqs, &mut rng, scheduler.effective_budget())
                .unwrap()
                .iter()
                .map(fingerprint)
                .collect()
        })
        .collect();
    (out, metrics)
}

#[test]
fn warm_serving_is_bit_identical_across_modes_and_temperatures() {
    let turns = session_turns();
    for mode in [DecodeMode::Continuous, DecodeMode::Wave] {
        for temp in [0.0, 1.0] {
            let (cold, cm) = serve_turns(cache_config(mode, temp, false), &turns);
            let (warm, wm) = serve_turns(cache_config(mode, temp, true), &turns);
            assert_eq!(
                cold, warm,
                "cache-on diverged from cache-off at mode={mode:?} temp={temp}"
            );
            // cache off ⇒ the scheduler records no prefix activity at all
            assert_eq!(cm.counter("serving.prefix.hit").get(), 0);
            match mode {
                // non-vacuous: the warm run actually reused prefixes
                DecodeMode::Continuous => assert!(
                    wm.counter("serving.prefix.hit").get() > 0,
                    "no prefix hits at temp={temp} — parity is vacuous"
                ),
                // wave mode re-encodes full batches and never touches the
                // slot API; the cache must stay inert there
                DecodeMode::Wave => assert_eq!(
                    wm.counter("serving.prefix.hit").get()
                        + wm.counter("serving.prefix.miss").get(),
                    0,
                    "wave mode must not consult the prefix cache"
                ),
            }
        }
    }
}

#[test]
fn eviction_under_pressure_keeps_bit_parity() {
    // a cache barely big enough for one snapshot: every insert evicts the
    // previous resident, and prefixes evicted on turn t get re-filled on
    // turn t+1 — served bytes must not care
    let turns = session_turns();
    let (cold, _) = serve_turns(
        cache_config(DecodeMode::Continuous, 1.0, false),
        &turns,
    );
    let mut cfg = cache_config(DecodeMode::Continuous, 1.0, true);
    cfg.prefix_cache.max_bytes = 150;
    let (warm, wm) = serve_turns(cfg, &turns);
    assert_eq!(cold, warm, "eviction pressure changed served output");
    assert!(
        wm.gauge("serving.prefix.evict").get() > 0.0,
        "cache never evicted — pressure case is vacuous"
    );
}

// ---- pool parity: same trace through ShardPool at workers = 1 and 2 ----

struct CollectSink {
    ready: AtomicUsize,
    out: Mutex<BTreeMap<u64, (u64, String, bool, usize, u64, u32, String)>>,
    failure: Mutex<Option<String>>,
}

impl EpochSink for CollectSink {
    fn on_worker_ready(&self, _worker: usize) {
        self.ready.fetch_add(1, Ordering::SeqCst);
    }

    fn on_response(&self, resp: Response) {
        let prev = self.out.lock().unwrap().insert(resp.id, fingerprint(&resp));
        assert!(prev.is_none(), "duplicate response");
    }

    fn on_epoch_error(&self, _epoch: &[Request], err: &anyhow::Error, _el: Duration) {
        self.failure
            .lock()
            .unwrap()
            .get_or_insert_with(|| format!("epoch failed: {err:#}"));
    }

    fn on_fatal(&self, worker: usize, err: &anyhow::Error) {
        self.failure
            .lock()
            .unwrap()
            .get_or_insert_with(|| format!("worker {worker} failed: {err:#}"));
    }
}

fn run_pool(
    workers: usize,
    turns: &[Vec<Request>],
    mut cfg: Config,
) -> BTreeMap<u64, (u64, String, bool, usize, u64, u32, String)> {
    // one turn per epoch so warm turns can hit prefixes cached by cold ones
    cfg.server.batch_queries = turns[0].len();
    cfg.server.workers = workers;
    cfg.validate().unwrap();
    let n: usize = turns.iter().map(Vec::len).sum();
    let batcher = Arc::new(Batcher::new(
        cfg.server.batch_queries,
        Duration::from_millis(cfg.server.max_wait_ms),
    ));
    for reqs in turns {
        for r in reqs {
            assert!(batcher.submit(r.clone()));
        }
    }
    batcher.close();
    let shared = SchedulerShared::new(cfg, Arc::new(Registry::default()));
    let sink = Arc::new(CollectSink {
        ready: AtomicUsize::new(0),
        out: Mutex::new(BTreeMap::new()),
        failure: Mutex::new(None),
    });
    let pool = ShardPool::spawn(workers, batcher, shared, sink.clone());
    pool.join();
    if let Some(msg) = sink.failure.lock().unwrap().as_ref() {
        panic!("{msg}");
    }
    let out = std::mem::take(&mut *sink.out.lock().unwrap());
    assert_eq!(out.len(), n, "lost responses");
    out
}

#[test]
fn pool_parity_at_temperature_zero_across_widths() {
    // temperature 0: worker identity and epoch interleaving are already
    // unobservable (pinned by decode_engine.rs), so any divergence here is
    // the cache's — compare all four (cache × width) runs pairwise
    let turns = session_turns();
    let base = run_pool(1, &turns, cache_config(DecodeMode::Continuous, 0.0, false));
    for workers in [1, 2] {
        for cache in [false, true] {
            let got = run_pool(
                workers,
                &turns,
                cache_config(DecodeMode::Continuous, 0.0, cache),
            );
            for (id, want) in &base {
                assert_eq!(
                    &got[id], want,
                    "request {id} diverged at workers={workers} cache={cache}"
                );
            }
        }
    }
}
