//! Backend parity and determinism contracts for the native backend
//! (see `runtime::backend`'s trait docs — these tests pin them):
//!
//! * the same pre-cut epoch trace served through a `workers = 1` pool and a
//!   `workers = 2` pool produces *identical* per-request budgets, rewards
//!   and routing decisions (backend purity + deterministic allocation; at
//!   temperature 0 the sampler's rng never participates, so worker
//!   identity is unobservable);
//! * a `workers = 1` pool is bit-for-bit reproducible across whole runs
//!   even at temperature > 0 (worker 0 keeps the historical scheduler
//!   seed);
//! * the `xla-runtime` feature still builds the trait impl (compile-only).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use thinkalloc::config::{AllocPolicy, Config, ProcedureKind};
use thinkalloc::metrics::Registry;
use thinkalloc::serving::batcher::Batcher;
use thinkalloc::serving::scheduler::SchedulerShared;
use thinkalloc::serving::shard::{EpochSink, ShardPool};
use thinkalloc::serving::{Request, Response};
use thinkalloc::workload;

/// Everything observable about a served request that must not depend on
/// pool width or run identity.
#[derive(Debug, Clone, PartialEq)]
struct Outcome {
    ok: bool,
    budget: usize,
    predicted: f64,
    reward: f32,
    response: String,
    procedure: ProcedureKind,
}

struct CollectSink {
    ready: AtomicUsize,
    out: Mutex<BTreeMap<u64, Outcome>>,
    failure: Mutex<Option<String>>,
}

impl EpochSink for CollectSink {
    fn on_worker_ready(&self, _worker: usize) {
        self.ready.fetch_add(1, Ordering::SeqCst);
    }

    fn on_response(&self, resp: Response) {
        let prev = self.out.lock().unwrap().insert(
            resp.id,
            Outcome {
                ok: resp.ok,
                budget: resp.budget,
                predicted: resp.predicted,
                reward: resp.reward,
                response: resp.response,
                procedure: resp.procedure,
            },
        );
        assert!(prev.is_none(), "duplicate response for id");
    }

    fn on_epoch_error(&self, _epoch: &[Request], err: &anyhow::Error, _el: Duration) {
        self.failure
            .lock()
            .unwrap()
            .get_or_insert_with(|| format!("epoch failed: {err:#}"));
    }

    fn on_fatal(&self, worker: usize, err: &anyhow::Error) {
        self.failure
            .lock()
            .unwrap()
            .get_or_insert_with(|| format!("worker {worker} engine load failed: {err:#}"));
    }
}

fn parity_config(temperature: f64) -> Config {
    let mut cfg = Config::default(); // runtime.backend = native
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.batch_queries = 16;
    cfg.server.max_wait_ms = 50;
    cfg.server.temperature = temperature;
    cfg.validate().unwrap();
    cfg
}

/// Mixed-domain trace, alternating decode procedures per request so both
/// the adaptive and routed paths are under the parity microscope.
fn epoch_trace(n: usize) -> Vec<Request> {
    workload::gen_mixed_dataset(&["code", "math", "chat"], n, 0x9A417)
        .into_iter()
        .enumerate()
        .map(|(i, q)| {
            let mut r = Request::new(i as u64, q.text, q.domain);
            r.procedure = Some(if i % 2 == 0 {
                ProcedureKind::AdaptiveBestOfK
            } else {
                ProcedureKind::WeakStrongRoute
            });
            r
        })
        .collect()
}

/// Serve `reqs` through a `workers`-wide native pool; requests are
/// pre-submitted and the batcher closed before the pool spawns, so epoch
/// boundaries are identical FIFO cuts regardless of pool width.
fn run_pool(workers: usize, reqs: &[Request], cfg: Config) -> BTreeMap<u64, Outcome> {
    let batcher = Arc::new(Batcher::new(
        cfg.server.batch_queries,
        Duration::from_millis(cfg.server.max_wait_ms),
    ));
    for r in reqs {
        assert!(batcher.submit(r.clone()));
    }
    batcher.close();
    let shared = SchedulerShared::new(cfg, Arc::new(Registry::default()));
    let sink = Arc::new(CollectSink {
        ready: AtomicUsize::new(0),
        out: Mutex::new(BTreeMap::new()),
        failure: Mutex::new(None),
    });
    let pool = ShardPool::spawn(workers, batcher, shared, sink.clone());
    pool.join();
    if let Some(msg) = sink.failure.lock().unwrap().as_ref() {
        panic!("{msg}");
    }
    let out = std::mem::take(&mut *sink.out.lock().unwrap());
    assert_eq!(out.len(), reqs.len(), "lost responses");
    out
}

#[test]
fn native_pool_width_is_unobservable_at_temperature_zero() {
    // At temperature 0 generation is greedy (the sampler's rng is never
    // consumed), so every per-request outcome must be a pure function of
    // the epoch trace — identical across workers=1 and workers=2 even
    // though different worker threads (with different rng seeds) serve the
    // epochs.
    let reqs = epoch_trace(64);
    let one = run_pool(1, &reqs, parity_config(0.0));
    let two = run_pool(2, &reqs, parity_config(0.0));
    assert_eq!(one.len(), two.len());
    for (id, a) in &one {
        let b = &two[id];
        assert_eq!(a, b, "request {id} diverged between workers=1 and workers=2");
    }
    // sanity: the trace actually exercised both procedures and both arms
    let routed = one
        .values()
        .filter(|o| o.procedure == ProcedureKind::WeakStrongRoute)
        .count();
    assert_eq!(routed, 32, "half the trace pins the routed procedure");
    assert!(one.values().any(|o| o.budget == 0), "no predicted-impossible query");
    assert!(one.values().any(|o| o.budget > 1), "no multi-sample allocation");
}

#[test]
fn native_single_worker_is_bit_for_bit_reproducible() {
    // workers = 1 keeps the historical scheduler seed: two fresh pools over
    // the same trace must agree bit-for-bit even with stochastic sampling.
    let reqs = epoch_trace(48);
    let a = run_pool(1, &reqs, parity_config(0.7));
    let b = run_pool(1, &reqs, parity_config(0.7));
    for (id, oa) in &a {
        assert_eq!(oa, &b[id], "run-to-run divergence at request {id}");
    }
}

#[test]
fn native_predictions_survive_the_cache_identically() {
    // cache-on vs cache-off predictions must be bit-identical (backend
    // purity is what makes the prediction cache sound)
    let reqs = epoch_trace(32);
    let mut cached = parity_config(0.0);
    cached.server.predict_cache_capacity = 1024;
    let mut uncached = parity_config(0.0);
    uncached.server.predict_cache_capacity = 0;
    let a = run_pool(1, &reqs, cached);
    let b = run_pool(1, &reqs, uncached);
    for (id, oa) in &a {
        assert_eq!(
            oa.predicted, b[id].predicted,
            "cache changed the prediction for request {id}"
        );
        assert_eq!(oa.budget, b[id].budget);
    }
}

/// Compile-only: the feature-gated xla backend still satisfies the trait.
/// This test body is trivial — the value is that `cargo check --features
/// xla-runtime --tests` type-checks the impl against the trait.
#[cfg(feature = "xla-runtime")]
#[test]
fn xla_backend_still_implements_the_trait() {
    fn is_backend<T: thinkalloc::runtime::backend::Backend>() {}
    is_backend::<thinkalloc::runtime::backend::xla::XlaBackend>();
}
