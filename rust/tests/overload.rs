//! Overload-safety integration: graceful shutdown with open connections,
//! oversize-line rejection, slow-reader isolation, staged admission
//! (degrade → shed) under a flooded batcher, the bit-for-bit parity
//! contract at sub-saturation, and the bounded-queue backstop. Runs on the
//! default native backend — no artifacts required (CI gates on this).
//!
//! The whole suite is the regression harness for the I/O drivers: CI runs
//! it twice, once per `io_mode`, via `THINKALLOC_IO_MODE=threads|event`
//! (default: the config default, `event`). The front-door invariants must
//! hold identically under both drivers.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use thinkalloc::config::{AllocPolicy, Config, IoMode};
use thinkalloc::jsonio::Json;
use thinkalloc::metrics::Registry;
use thinkalloc::server::{Client, Server};

/// Base config: native backend, online policy, small budgets — fast on CI.
/// `THINKALLOC_IO_MODE` (the CI matrix axis) overrides the I/O driver.
fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.allocator.policy = AllocPolicy::Online;
    cfg.allocator.budget_per_query = 2.0;
    cfg.allocator.b_max = 8;
    cfg.server.addr = "127.0.0.1:0".into();
    if let Ok(m) = std::env::var("THINKALLOC_IO_MODE") {
        if !m.is_empty() {
            cfg.server.io_mode = m.parse().expect("THINKALLOC_IO_MODE: event|threads");
        }
    }
    cfg
}

fn start(cfg: Config) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::new(cfg, Arc::new(Registry::default()));
    let (tx, rx) = mpsc::channel();
    let srv = server.clone();
    let handle = std::thread::spawn(move || srv.run(|a| tx.send(a).unwrap()));
    (rx.recv().unwrap(), handle)
}

/// Shutdown with idle connections still open must terminate: readers
/// blocked on the socket used to wedge `run()` forever (they blocked in
/// `lines()` with nothing to join them). Now every connection's socket is
/// shut down, both its threads are joined, and every client sees EOF.
#[test]
fn shutdown_with_open_connections_joins_and_clients_get_eof() {
    let mut cfg = base_cfg();
    cfg.server.batch_queries = 2;
    cfg.server.max_wait_ms = 10;
    cfg.validate().unwrap();
    let (addr, handle) = start(cfg);

    // two idle connections that never send a byte — the pre-fix server
    // leaked a blocked reader thread for each
    let mut idle_a = Client::connect(&addr).unwrap();
    let mut idle_b = Client::connect(&addr).unwrap();
    idle_a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    idle_b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // a working connection proves the server is live before shutdown
    let mut active = Client::connect(&addr).unwrap();
    active.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    active.request(1, "ADD 1 2", "code").unwrap();
    let resp = active.read_response().unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_i64), Some(1));

    active.command("shutdown").unwrap();

    // run() must return — it joins every reader and writer on the way out
    handle
        .join()
        .expect("server thread panicked")
        .expect("server run() errored");

    // every client — idle or not — sees a clean EOF, not a hang
    assert!(idle_a.read_response().is_err(), "idle client A expected EOF");
    assert!(idle_b.read_response().is_err(), "idle client B expected EOF");
    assert!(active.read_response().is_err(), "active client expected EOF");
}

/// A request line longer than `server.max_line_bytes` fails the connection
/// with a structured error instead of growing the read buffer without
/// bound; other connections are unaffected.
#[test]
fn oversize_line_fails_connection_with_structured_error() {
    let mut cfg = base_cfg();
    cfg.server.batch_queries = 1;
    cfg.server.max_wait_ms = 5;
    cfg.server.max_line_bytes = 1024; // the validation floor
    cfg.validate().unwrap();
    let (addr, handle) = start(cfg);

    let mut abuser = Client::connect(&addr).unwrap();
    abuser.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    // 4 KiB of garbage on one line: 4x the cap
    abuser.write_raw(&"x".repeat(4096)).unwrap();
    let resp = abuser.read_response().unwrap();
    let err = resp.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(
        err.contains("line exceeds 1024 bytes"),
        "expected the oversize error line, got {resp:?}"
    );
    // the connection is then closed
    assert!(abuser.read_response().is_err(), "abuser expected EOF");

    // a well-behaved connection is served normally afterwards
    let mut ok = Client::connect(&addr).unwrap();
    ok.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    ok.request(7, "ADD 2 3", "code").unwrap();
    let resp = ok.read_response().unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_i64), Some(7));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));

    ok.command("shutdown").unwrap();
    let _ = handle.join();
}

/// A connection that submits work but never reads its responses must not
/// delay other connections: workers deliver into per-connection outboxes,
/// never directly onto sockets, so the fast client's responses flow while
/// the slow client's sit in its own queue.
#[test]
fn slow_reader_does_not_delay_other_connections() {
    let mut cfg = base_cfg();
    cfg.server.workers = 1; // one worker: any cross-connection stall shows
    cfg.server.batch_queries = 4;
    cfg.server.max_wait_ms = 10;
    cfg.server.outbox_depth = 4;
    cfg.server.writer_stall_ms = 200;
    cfg.validate().unwrap();
    let (addr, handle) = start(cfg);

    // the slow client floods requests and never reads a single response
    let mut slow = Client::connect(&addr).unwrap();
    for i in 0..12 {
        slow.request(i, "ADD 1 1", "code").unwrap();
    }

    // the fast client must get every one of its responses regardless
    let mut fast = Client::connect(&addr).unwrap();
    fast.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut got = std::collections::BTreeSet::new();
    for i in 0..12 {
        fast.request(100 + i, "ADD 2 2", "code").unwrap();
    }
    for _ in 0..12 {
        let resp = fast.read_response().expect("fast client starved");
        got.insert(resp.get("id").and_then(Json::as_i64).unwrap());
    }
    assert_eq!(got.len(), 12, "fast client missing responses");
    assert!(got.iter().all(|id| (100..112).contains(id)));

    fast.command("shutdown").unwrap();
    drop(slow);
    let _ = handle.join();
}

/// Flooding a bounded batcher with admission enabled walks the staged
/// response deterministically: the first submissions are accepted, the
/// next band is degraded onto the weak routing arm, everything past the
/// shed threshold is rejected with `overloaded` + a retry hint — and the
/// counters account for every query.
#[test]
fn admission_degrades_then_sheds_under_flood() {
    let mut cfg = base_cfg();
    cfg.server.workers = 1;
    // epoch cuts only at 64 queries or 500 ms: the flood of 64 lands while
    // the batcher is still accumulating, so queue depth climbs 0,1,2,…
    // exactly one step per accepted request
    cfg.server.batch_queries = 64;
    cfg.server.max_wait_ms = 500;
    cfg.server.max_queue_depth = 8;
    cfg.admission.enabled = true;
    cfg.admission.degrade_at = 0.25; // depth ≥ 2
    cfg.admission.shed_at = 0.75; // depth ≥ 6
    cfg.admission.hysteresis = 0.1;
    cfg.admission.retry_after_ms = 100;
    cfg.validate().unwrap();
    let (addr, handle) = start(cfg);

    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    // one write_raw call = one burst: all 64 lines are on the wire before
    // the 500 ms epoch deadline can fire
    let burst: String = (0..64)
        .map(|i| format!(r#"{{"id": {i}, "text": "ADD 1 2", "domain": "code"}}"#))
        .collect::<Vec<_>>()
        .join("\n");
    c.write_raw(&burst).unwrap();

    // depth walk: 0,1 → accept (2); 2..5 → degrade (4); ≥6 → shed (58)
    let mut accepted = 0u32;
    let mut degraded = 0u32;
    let mut shed = 0u32;
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..64 {
        let resp = c.read_response().expect("one line per query");
        let id = resp.get("id").and_then(Json::as_i64).expect("id on every line");
        assert!(seen.insert(id), "id {id} answered twice");
        if resp.get("error").is_some() {
            assert_eq!(
                resp.get("error").and_then(Json::as_str),
                Some("overloaded"),
                "unexpected error line: {resp:?}"
            );
            let retry = resp
                .get("retry_after_ms")
                .and_then(Json::as_i64)
                .expect("shed lines carry a retry hint");
            assert!(retry >= 100, "retry hint below the configured base");
            shed += 1;
        } else {
            // degraded queries are stamped with the weak-arm procedure
            match resp.get("procedure").and_then(Json::as_str) {
                Some("route") => degraded += 1,
                Some("adaptive") => accepted += 1,
                other => panic!("unexpected procedure {other:?}"),
            }
        }
    }
    assert_eq!(seen.len(), 64, "every query answered exactly once");
    assert_eq!((accepted, degraded, shed), (2, 4, 58));

    // the admission counters agree with the wire
    let metrics = c.command("metrics").unwrap();
    let counter = |name: &str| metrics.get(name).and_then(Json::as_f64);
    assert_eq!(counter("counter.serving.admission.accepted"), Some(2.0));
    assert_eq!(counter("counter.serving.admission.degraded"), Some(4.0));
    assert_eq!(counter("counter.serving.admission.shed"), Some(58.0));

    c.command("shutdown").unwrap();
    let _ = handle.join();
}

/// The parity contract: at sub-saturation load, enabling admission must
/// not change a single bit of any response. Two closed-loop runs — one
/// with admission off, one with it on — produce field-for-field identical
/// responses (latency excluded: it measures wall time, not behavior).
#[test]
fn admission_disabled_is_bit_for_bit_inert_at_subsaturation() {
    let run = |admission: bool| -> (Vec<Json>, Json) {
        let mut cfg = base_cfg();
        cfg.server.workers = 1; // single seeded worker ⇒ deterministic run
        cfg.server.batch_queries = 1;
        cfg.server.max_wait_ms = 5;
        cfg.admission.enabled = admission;
        cfg.validate().unwrap();
        let (addr, handle) = start(cfg);
        let mut c = Client::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let mut out = Vec::new();
        // closed loop: depth is ~0 at every admission decision
        for i in 0..12 {
            let text = format!("ADD {} {}", i, i + 1);
            c.request(i, &text, if i % 2 == 0 { "code" } else { "math" })
                .unwrap();
            out.push(c.read_response().unwrap());
        }
        let metrics = c.command("metrics").unwrap();
        c.command("shutdown").unwrap();
        let _ = handle.join();
        (out, metrics)
    };

    let (off, off_metrics) = run(false);
    let (on, on_metrics) = run(true);
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        for field in ["id", "response", "ok", "budget", "predicted", "reward", "procedure"] {
            assert_eq!(
                a.get(field),
                b.get(field),
                "response {i} field {field} diverged with admission on"
            );
        }
    }
    // enabled: all 12 accepted, nothing degraded or shed
    assert_eq!(
        on_metrics.get("counter.serving.admission.accepted").and_then(Json::as_f64),
        Some(12.0)
    );
    assert!(on_metrics.get("counter.serving.admission.degraded").is_none());
    assert!(on_metrics.get("counter.serving.admission.shed").is_none());
    // disabled: the admission counters don't even exist
    for k in [
        "counter.serving.admission.accepted",
        "counter.serving.admission.degraded",
        "counter.serving.admission.shed",
    ] {
        assert!(off_metrics.get(k).is_none(), "{k} must not exist when disabled");
    }
}

/// The io-mode parity contract: the event loop and the thread-per-
/// connection driver speak byte-identical wire protocol. A deterministic
/// single-worker run under each driver must produce field-for-field
/// identical responses (latency excluded: wall time, not behavior) —
/// including error lines for malformed input.
#[test]
fn io_modes_serve_identical_wire_responses() {
    let run = |mode: IoMode| -> Vec<Json> {
        let mut cfg = base_cfg();
        cfg.server.io_mode = mode; // pin explicitly: this test IS the matrix
        cfg.server.workers = 1; // single seeded worker ⇒ deterministic run
        cfg.server.batch_queries = 1;
        cfg.server.max_wait_ms = 5;
        cfg.validate().unwrap();
        let (addr, handle) = start(cfg);
        let mut c = Client::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let mut out = Vec::new();
        for i in 0..8 {
            let text = format!("ADD {} {}", i, i + 1);
            c.request(i, &text, if i % 2 == 0 { "code" } else { "math" })
                .unwrap();
            out.push(c.read_response().unwrap());
        }
        // error paths must match too: bad id, bad procedure, unknown cmd,
        // non-JSON garbage
        for raw in [
            r#"{"id": -3, "text": "ADD 1 1", "domain": "code"}"#,
            r#"{"id": 1, "text": "ADD 1 1", "procedure": "warp"}"#,
            r#"{"cmd": "dance"}"#,
            "not json at all",
        ] {
            c.write_raw(raw).unwrap();
            out.push(c.read_response().unwrap());
        }
        c.command("shutdown").unwrap();
        let _ = handle.join();
        out
    };

    let threads = run(IoMode::Threads);
    let event = run(IoMode::Event);
    assert_eq!(threads.len(), event.len());
    for (i, (a, b)) in threads.iter().zip(&event).enumerate() {
        for field in [
            "id", "response", "ok", "budget", "predicted", "reward", "procedure",
            "error", "retry_after_ms",
        ] {
            assert_eq!(
                a.get(field),
                b.get(field),
                "response {i} field {field} diverged between io modes"
            );
        }
    }
}

/// The event loop's reason to exist: many concurrent connections on O(1)
/// threads. A batch of idle connections plus one active one — the live
/// gauge counts them, requests are served among the idle crowd, and the
/// loop telemetry (wakeups/read/write events) shows up in the dump.
#[test]
fn event_loop_holds_many_idle_connections() {
    let mut cfg = base_cfg();
    cfg.server.io_mode = IoMode::Event;
    cfg.server.io_threads = 2;
    cfg.server.batch_queries = 1;
    cfg.server.max_wait_ms = 5;
    cfg.validate().unwrap();
    let (addr, handle) = start(cfg);

    let idle: Vec<Client> = (0..48)
        .map(|_| Client::connect(&addr).unwrap())
        .collect();
    for c in &idle {
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    }

    let mut active = Client::connect(&addr).unwrap();
    active.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    active.request(9, "ADD 3 4", "code").unwrap();
    let resp = active.read_response().unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_i64), Some(9));

    let metrics = active.command("metrics").unwrap();
    let live = metrics
        .get("gauge.serving.conn.live")
        .and_then(Json::as_f64)
        .expect("live-connection gauge must exist in event mode");
    // 48 idle + 1 active, allowing for accept/registration in flight
    assert!(live >= 40.0 && live <= 49.0, "unexpected live gauge {live}");
    for k in [
        "counter.serving.io.wakeups",
        "counter.serving.io.read_events",
        "counter.serving.io.write_events",
    ] {
        assert!(
            metrics.get(k).and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "{k} must be live in event mode"
        );
    }

    active.command("shutdown").unwrap();
    handle.join().unwrap().unwrap();
    // every idle connection gets a clean EOF on shutdown
    for mut c in idle {
        assert!(c.read_response().is_err(), "idle client expected EOF");
    }
}

/// A reader that disconnects with requests still queued must have its
/// routing entries purged *eagerly* — before any response comes back — and
/// its queued work reclaimed by the pre-epoch sweep instead of being
/// decoded for nobody. Pre-fix, the routing map grew one orphan per
/// abandoned request until a response happened to arrive.
#[test]
fn reader_disconnect_purges_routing_and_reclaims_queued_work() {
    let mut cfg = base_cfg();
    cfg.server.workers = 1;
    // a 64-query epoch that won't cut for 500 ms: the ghost's requests are
    // still *queued* (not served) for the whole observation window
    cfg.server.batch_queries = 64;
    cfg.server.max_wait_ms = 500;
    cfg.validate().unwrap();
    let (addr, handle) = start(cfg);

    let mut observer = Client::connect(&addr).unwrap();
    observer.set_read_timeout(Some(Duration::from_secs(120))).unwrap();

    let mut ghost = Client::connect(&addr).unwrap();
    let burst: String = (0..8)
        .map(|i| format!(r#"{{"id": {i}, "text": "ADD 1 2", "domain": "code"}}"#))
        .collect::<Vec<_>>()
        .join("\n");
    ghost.write_raw(&burst).unwrap();

    // the stats verb reports the routing-map size as `inflight`
    let inflight = |c: &mut Client| -> f64 {
        c.command("stats")
            .unwrap()
            .get("inflight")
            .and_then(Json::as_f64)
            .expect("stats carries inflight")
    };
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while inflight(&mut observer) < 8.0 {
        assert!(
            std::time::Instant::now() < deadline,
            "ghost requests never became in-flight"
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    // reader exit must purge the 8 entries NOW — the epoch (and therefore
    // any response-time cleanup) is still hundreds of ms away
    drop(ghost);
    while inflight(&mut observer) > 0.0 {
        assert!(
            std::time::Instant::now() < deadline,
            "routing entries for the dead connection were not purged"
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    // …and once the epoch cuts, the sweep drops the orphaned work without
    // spending a decode step on it
    loop {
        let metrics = observer.command("metrics").unwrap();
        let reclaimed = metrics
            .get("counter.serving.cancelled.queued")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if reclaimed >= 8.0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "queued orphans were not reclaimed by the pre-epoch sweep \
             (got {reclaimed})"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    observer.command("shutdown").unwrap();
    let _ = handle.join();
}

/// With admission disabled, the bounded queue is still a hard backstop:
/// requests past `max_queue_depth` draw `overloaded` lines instead of
/// growing the queue without bound (the pre-fix failure mode).
#[test]
fn queue_full_backstop_sheds_without_admission() {
    let mut cfg = base_cfg();
    cfg.server.workers = 1;
    cfg.server.batch_queries = 64; // epoch cuts on the 500 ms deadline only
    cfg.server.max_wait_ms = 500;
    cfg.server.max_queue_depth = 4;
    cfg.validate().unwrap();
    assert!(!cfg.admission.enabled, "this test exercises the backstop only");
    let (addr, handle) = start(cfg);

    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let burst: String = (0..16)
        .map(|i| format!(r#"{{"id": {i}, "text": "ADD 1 2", "domain": "code"}}"#))
        .collect::<Vec<_>>()
        .join("\n");
    c.write_raw(&burst).unwrap();

    let mut served = 0u32;
    let mut shed = 0u32;
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..16 {
        let resp = c.read_response().unwrap();
        let id = resp.get("id").and_then(Json::as_i64).expect("id on every line");
        assert!(seen.insert(id), "id {id} answered twice");
        if resp.get("error").is_some() {
            assert_eq!(resp.get("error").and_then(Json::as_str), Some("overloaded"));
            assert!(
                resp.get("retry_after_ms").and_then(Json::as_i64).unwrap_or(0) > 0,
                "backstop rejections still carry a retry hint"
            );
            shed += 1;
        } else {
            served += 1;
        }
    }
    assert_eq!((served, shed), (4, 12), "queue bound is exactly max_queue_depth");

    let metrics = c.command("metrics").unwrap();
    assert_eq!(
        metrics.get("counter.serving.admission.shed").and_then(Json::as_f64),
        Some(12.0)
    );
    // no admission ⇒ no accepted/degraded counters, only the backstop's shed
    assert!(metrics.get("counter.serving.admission.accepted").is_none());
    assert!(metrics.get("counter.serving.admission.degraded").is_none());

    c.command("shutdown").unwrap();
    let _ = handle.join();
}
