"""Synthetic task universe (the datasets substitution — see DESIGN.md §5).

Three domains mirror the paper's evaluation suites:

* **code** (TACO-like):  ``ADD v1 v2 ... vk`` → answer ``(Σ v) % 100``.
  Difficulty grows with operand count ``k``; instances with ``k > 8`` have
  ground-truth success probability λ = 0, so that (with k ~ U{1..16}) ~50% of
  the dataset is impossible — reproducing Fig. 3's Code left panel and the
  online-allocation pathology discussed in §4.1.
* **math** (Numina-like): ``REV s`` → answer ``reversed(s)``.  λ decays
  smoothly with ``len(s)``; ~5% of instances are impossible, giving the
  flatter difficulty histogram of Fig. 3's Math left panel.
* **chat** (LMSYS-like):  ``CHAT w1 ... wm`` — open-ended; a per-query reward
  distribution N(μ(x), σ(x)) replaces the NCSOFT reward model.  The routing
  settings reuse chat queries with a strong-decoder gain g(x) that is
  *sometimes negative* (the paper's "weak decoder sometimes wins").

All ground-truth functions are integer/affine arithmetic on query features and
are mirrored *exactly* in ``rust/src/workload/`` (property-tested against the
JSON goldens exported by aot.py).  Every generator is a pure function of an
explicit PRNG so datasets are reproducible across the two languages.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# 64-word chat vocabulary; single-character words so identity survives the
# byte-level tokenizer (multi-byte words would smear identity across byte
# bigrams, which mean-pooled probes cannot recover — verified empirically).
# Weights are pure index formulas (rust-mirrorable).
CHAT_ALPHABET = ("ABCDEFGHIJKLMNOPQRSTUVWXYZ"
                 "abcdefghijklmnopqrstuvwxyz"
                 "0123456789!?")
CHAT_WORDS = list(CHAT_ALPHABET)
assert len(CHAT_WORDS) == 64


def chat_weight(i: int) -> float:
    return ((7 * i) % 13 - 6) / 10.0


def chat_volatile(i: int) -> bool:
    return i % 5 == 0


def route_gain_weight(i: int) -> float:
    return ((11 * i) % 19 - 7) / 12.0


def vas_gain_weight(i: int) -> float:
    return ((5 * i) % 11 - 4) / 30.0


@dataclass
class Query:
    text: str          # what the LM sees (before " =")
    answer: str        # ground-truth completion for the exact-match verifier
    lam: float         # ground-truth single-sample success probability λ(x)
    mu: float          # chat: mean reward of one sample
    sigma: float       # chat: std of sample reward
    gain: float        # routing: strong-decoder mean advantage
    gain_vas: float    # routing (VAS): strong-procedure mean advantage
    domain: str


# --- code domain ------------------------------------------------------------
def code_lambda(k: int, big: int) -> float:
    """λ for an ADD query with k operands, `big` of which are ≥ 50."""
    if k > 8:
        return 0.0
    lam = 0.92 * (0.58 ** (k - 1)) * (0.92 ** big)
    return float(min(max(lam, 0.0), 1.0))


def gen_code(rng: np.random.Generator) -> Query:
    k = int(rng.integers(1, 17))
    vals = [int(rng.integers(0, 100)) for _ in range(k)]
    big = sum(1 for v in vals if v >= 50)
    text = "ADD " + " ".join(str(v) for v in vals)
    ans = str(sum(vals) % 100)
    return Query(text, ans, code_lambda(k, big), 0.0, 0.0, 0.0, 0.0, "code")


# --- math domain ------------------------------------------------------------
def math_lambda(length: int, vowels: int) -> float:
    lam = 1.02 - 0.042 * length - 0.02 * vowels
    return float(min(max(lam, 0.0), 1.0))


def gen_math(rng: np.random.Generator) -> Query:
    length = int(rng.integers(1, 25))
    letters = "abcdefghijklmnopqrstuvwxyz"
    s = "".join(letters[int(rng.integers(0, 26))] for _ in range(length))
    vowels = sum(1 for c in s if c in "aeiou")
    return Query("REV " + s, s[::-1], math_lambda(length, vowels),
                 0.0, 0.0, 0.0, 0.0, "math")


# --- chat / routing domains --------------------------------------------------
def chat_params(word_idx: list[int]) -> tuple[float, float, float, float]:
    """Per-query reward/preference parameters.

    All four parameters are affine in the bag-of-words *mean* weight — the
    statistic a probe on mean-pooled hidden states can recover exactly.
    Amplification factors are tuned so the preference distribution spans the
    paper's Fig. 5 left panels (model-size wide, VAS low-entropy) despite the
    CLT shrink from averaging over m words.
    """
    m = len(word_idx)
    mu = 1.0 + 1.8 * sum(chat_weight(i) for i in word_idx) / m
    vol = sum(1 for i in word_idx if chat_volatile(i))
    sigma = 0.25 + 0.55 * vol / m
    gain = 2.2 * sum(route_gain_weight(i) for i in word_idx) / m
    gain_vas = 0.22 + 1.2 * sum(vas_gain_weight(i) for i in word_idx) / m
    return mu, sigma, gain, gain_vas


def gen_chat(rng: np.random.Generator) -> Query:
    m = int(rng.integers(2, 11))
    idx = [int(rng.integers(0, 64)) for _ in range(m)]
    mu, sigma, gain, gain_vas = chat_params(idx)
    text = "CHAT " + " ".join(CHAT_WORDS[i] for i in idx)
    return Query(text, "", 0.0, mu, sigma, gain, gain_vas, "chat")


GENERATORS = {"code": gen_code, "math": gen_math, "chat": gen_chat}


def gen_dataset(domain: str, n: int, seed: int) -> list[Query]:
    rng = np.random.default_rng(seed)
    return [GENERATORS[domain](rng) for _ in range(n)]


# --- sampled outcomes (what the verifier / reward model would say) -----------
def sample_binary_outcomes(qs: list[Query], k: int, seed: int) -> np.ndarray:
    """n×k Bernoulli(λ) outcome matrix — the synthetic verifier."""
    rng = np.random.default_rng(seed)
    lam = np.asarray([q.lam for q in qs])[:, None]
    return (rng.random((len(qs), k)) < lam).astype(np.float32)


def sample_chat_rewards(qs: list[Query], k: int, seed: int) -> np.ndarray:
    """n×k reward matrix r ~ N(μ(x), σ(x)), clipped to [-2, 4]."""
    rng = np.random.default_rng(seed)
    mu = np.asarray([q.mu for q in qs])[:, None]
    sg = np.asarray([q.sigma for q in qs])[:, None]
    return np.clip(rng.normal(mu, sg, (len(qs), k)), -2.0, 4.0).astype(np.float32)


def sample_routing_rewards(
    qs: list[Query], k: int, seed: int, vas: bool = False
) -> tuple[np.ndarray, np.ndarray]:
    """(weak n×k, strong n×k) reward matrices for a routing setting."""
    rng = np.random.default_rng(seed)
    mu = np.asarray([q.mu for q in qs])[:, None]
    g = np.asarray([(q.gain_vas if vas else q.gain) for q in qs])[:, None]
    sw = 0.35 if not vas else 0.3
    ss = 0.30 if not vas else 0.25
    weak = rng.normal(mu, sw, (len(qs), k))
    strong = rng.normal(mu + g, ss, (len(qs), k))
    return (np.clip(weak, -2, 4).astype(np.float32),
            np.clip(strong, -2, 4).astype(np.float32))


def preference_prob(qs: list[Query], n_mc: int, seed: int, vas: bool = False) -> np.ndarray:
    """Monte-Carlo estimate of p(S ≻ W | x) = E σ(r_S − r_W)  (paper eq. 8/11)."""
    weak, strong = sample_routing_rewards(qs, n_mc, seed, vas)
    return (1.0 / (1.0 + np.exp(-(strong - weak)))).mean(axis=1).astype(np.float32)


# --- LM pretraining corpus ----------------------------------------------------
def corpus_line(rng: np.random.Generator) -> str:
    """One supervised line ``<query> = <answer>`` for next-token pretraining.

    Chat lines are a copy-first-word task: predicting the completion forces
    the encoder to represent *which* words appear, which is exactly what the
    chat/routing probes need to read off the hidden state (the paper's
    premise that pretraining already encodes difficulty signal — here the
    pretraining objective is what puts it there).
    """
    r = rng.random()
    if r < 0.35:
        q = gen_code(rng)
    elif r < 0.7:
        q = gen_math(rng)
    else:
        q = gen_chat(rng)
        return q.text + " = " + q.text.split()[1]
    return q.text + " = " + q.answer


def gen_corpus(n: int, seed: int) -> list[str]:
    rng = np.random.default_rng(seed)
    return [corpus_line(rng) for _ in range(n)]
