"""Build-time configuration for the TinyLM stack.

Everything here is mirrored on the rust side in `rust/src/config/model.rs`
(shapes baked into the exported HLO artifacts) — keep the two in sync. The
`ARTIFACT_BATCH` sizes are the static PJRT batch shapes rust pads to.
"""

from dataclasses import dataclass, field


# --- tokenizer (byte-level; mirrored in rust/src/tokenizer) ----------------
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB = 259          # 256 bytes + PAD/BOS/EOS
VOCAB_PADDED = 320   # embedding rows padded for lane alignment

MAX_SEQ = 64         # static sequence length of every artifact
MAX_NEW_TOKENS = 24  # generation budget per sample in the decode loop


@dataclass(frozen=True)
class TinyLMConfig:
    """Decoder-only transformer used as encoder, generator and reward model."""

    vocab: int = VOCAB_PADDED
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = MAX_SEQ
    dropout: float = 0.0  # inference-only stack; kept for completeness

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class ProbeConfig:
    """Two-layer MLP difficulty probe on the encoder's last hidden state.

    `n_out` is 1 for the binary-λ heads (code/math, eq. 7) and for the
    preference heads (routing, eq. 8); it is `B_MAX_CHAT` for the chat
    marginal-reward vector head (eq. 6).
    """

    d_in: int = 128
    d_hidden: int = 128
    n_out: int = 1


@dataclass(frozen=True)
class TrainConfig:
    # LM pretraining
    lm_steps: int = 2400
    lm_batch: int = 64
    lm_lr: float = 2e-3
    lm_warmup: int = 100
    # probe training (lr > 1e-3 diverges on the standardized features of the
    # longer-trained encoder — NaN via GELU overflow)
    probe_steps: int = 2500
    probe_batch: int = 128
    probe_lr: float = 1e-3
    # reward head training
    reward_steps: int = 300
    reward_batch: int = 64
    reward_lr: float = 2e-3
    # LoRA fine-tune (math probe variant)
    lora_rank: int = 8
    lora_steps: int = 200
    lora_lr: float = 1e-3
    seed: int = 0


# --- domain dataset sizes ---------------------------------------------------
@dataclass(frozen=True)
class DomainSizes:
    n_train: int = 4096
    n_val: int = 512
    n_test: int = 2048


# max best-of-k budgets per domain (paper: 100 code / 128 math / 8 chat)
B_MAX_CODE = 100
B_MAX_MATH = 128
B_MAX_CHAT = 8

# static batch sizes of exported executables (rust pads to these)
ARTIFACT_BATCH = 64        # encoder / probes / reward
DECODE_BATCH = 32          # generation decode step

DEFAULT_TRAIN = TrainConfig()
DEFAULT_LM = TinyLMConfig()
DEFAULT_SIZES = DomainSizes()
