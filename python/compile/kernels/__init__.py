"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from .attention import attention
from .probe import probe_mlp
from .rerank import rerank
from .rmsnorm import rmsnorm

__all__ = ["attention", "probe_mlp", "rerank", "rmsnorm"]
