"""Fused difficulty-probe MLP Pallas kernel (paper §3.1, MLP variant).

One kernel computes σ(W2·GELU(W1·h + b1) + b2) for a block of queries: the
four matmul/bias/activation HLO ops (plus three HBM round-trips) collapse to
a single VMEM-resident pass. Weights are tiny (D=H=128, O≤8 ⇒ ~130 KiB f32)
and are broadcast to every grid step; activations stream through in
`block_b`-row tiles.

The same kernel serves all probe heads: λ̂ (binary-reward domains, sigmoid),
Δ̂ vector (chat MSE head, identity), and p̂(S≻W) (routing heads, sigmoid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def _probe_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, sigmoid: bool):
    h = h_ref[...].astype(jnp.float32)                  # [bb, D]
    z = h @ w1_ref[...].astype(jnp.float32) + b1_ref[...].astype(jnp.float32)
    z = 0.5 * z * (1.0 + jnp.tanh(_GELU_C * (z + 0.044715 * z * z * z)))
    out = z @ w2_ref[...].astype(jnp.float32) + b2_ref[...].astype(jnp.float32)
    if sigmoid:
        out = 1.0 / (1.0 + jnp.exp(-out))
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sigmoid", "block_b"))
def probe_mlp(h, w1, b1, w2, b2, *, sigmoid: bool = True, block_b: int = 64):
    """h: [B, D]; w1 [D,H]; b1 [H]; w2 [H,O]; b2 [O] → [B, O]."""
    b, d = h.shape
    hdim = w1.shape[1]
    o = w2.shape[1]
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    kernel = functools.partial(_probe_kernel, sigmoid=sigmoid)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, hdim), lambda i: (0, 0)),
            pl.BlockSpec((hdim,), lambda i: (0,)),
            pl.BlockSpec((hdim, o), lambda i: (0, 0)),
            pl.BlockSpec((o,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, o), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o), h.dtype),
        interpret=True,
    )(h, w1, b1, w2, b2)
