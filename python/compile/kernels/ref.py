"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

Each function here is the mathematical definition; the Pallas kernels in this
package must match them to float tolerance (enforced by
python/tests/test_kernels.py with hypothesis sweeps over shapes/seeds).
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_attention(q, k, v, mask, *, causal: bool = True):
    """Multi-head attention.

    q, k, v: [BH, S, D]   (batch×heads flattened)
    mask:    [BH, S]      1.0 at valid (non-PAD) key positions
    returns: [BH, S, D]
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bqd,bkd->bqk", q * scale, k)
    neg = jnp.asarray(-1e30, dtype=q.dtype)
    scores = jnp.where(mask[:, None, :] > 0, scores, neg)
    if causal:
        s = q.shape[1]
        tri = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(tri[None, :, :], scores, neg)
    # guard fully-masked rows (PAD queries): softmax over -1e30 rows is fine
    # numerically because we subtract the row max first.
    scores = scores - scores.max(axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / (w.sum(axis=-1, keepdims=True) + 1e-30)
    return jnp.einsum("bqk,bkd->bqd", w, v)


def ref_probe_mlp(h, w1, b1, w2, b2, *, sigmoid: bool = True):
    """Two-layer GELU MLP probe head.

    h: [B, D]; w1: [D, H]; b1: [H]; w2: [H, O]; b2: [O] → [B, O]
    """
    z = h @ w1 + b1
    z = 0.5 * z * (1.0 + jnp.tanh(0.7978845608028654 * (z + 0.044715 * z**3)))
    out = z @ w2 + b2
    return 1.0 / (1.0 + jnp.exp(-out)) if sigmoid else out


def ref_rerank(scores, mask):
    """Best-of-k arg-max reduce (paper eq. 1).

    scores: [B, K] candidate rewards; mask: [B, K] 1.0 for real candidates.
    returns (best_idx int32 [B], best_val [B]). Rows with no valid candidate
    return idx 0 and value -1e30.
    """
    neg = jnp.asarray(-1e30, dtype=scores.dtype)
    masked = jnp.where(mask > 0, scores, neg)
    idx = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    val = jnp.max(masked, axis=-1)
    return idx, val


def ref_rmsnorm(x, g, eps: float = 1e-6):
    """RMSNorm: x * g / rms(x).  x: [..., D], g: [D]."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * g
