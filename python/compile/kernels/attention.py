"""Fused multi-head attention Pallas kernel (flash-attention restated for TPU).

The paper's serving stacks lean on GPU flash-attention; the TPU restatement
(DESIGN.md §4) tiles the HBM→VMEM schedule with BlockSpec instead of
threadblocks: the grid walks (batch×head, q-block), each program streams
K/V in `block_k` tiles through VMEM while maintaining the online-softmax
running (max, denom, accumulator) so the S×S score matrix never materialises.

VMEM budget per program (f32, S=64, D=32, block_q=block_k=32):
q tile 32×32 + k/v tiles 32×32×2 + acc 32×32 + stats ≈ 20 KiB — far inside
the ~16 MiB/core budget; block sizes were chosen so the same BlockSpec scales
to S=2048 (q 128×128 + 2×k/v 128×128 + acc ≈ 256 KiB) with full MXU lanes.

interpret=True throughout: CPU PJRT cannot execute Mosaic custom-calls, so the
kernel lowers to plain HLO (while-loops) that the rust runtime runs; the
BlockSpec structure is what carries to real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *,
                 block_q: int, block_k: int, seq: int, causal: bool):
    qi = pl.program_id(1)
    d = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    q = q_ref[0, :, :].astype(jnp.float32) * scale            # [bq, d]
    row_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # [bq]

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.ds(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.ds(j * block_k, block_k), slice(None)))
        km = pl.load(mask_ref, (0, pl.ds(j * block_k, block_k)))
        s = q @ k.astype(jnp.float32).T                        # [bq, bk]
        col_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = km[None, :] > 0
        if causal:
            valid = valid & (col_ids[None, :] <= row_ids[:, None])
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # clamp so fully-masked rows (all -inf) don't produce NaN via inf-inf
        m_safe = jnp.maximum(m_new, -0.5e30)
        p = jnp.exp(s - m_safe[:, None])
        alpha = jnp.exp(jnp.maximum(m, -0.5e30) - m_safe)
        l_new = alpha * l + p.sum(axis=-1)
        acc_new = alpha[:, None] * acc + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    n_kb = seq // block_k
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    out = acc / (l + 1e-30)[:, None]
    o_ref[0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention(q, k, v, mask, *, causal: bool = True,
              block_q: int = 32, block_k: int = 32):
    """Fused attention. q,k,v: [BH, S, D]; mask: [BH, S] → [BH, S, D]."""
    bh, seq, d = q.shape
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    assert seq % block_q == 0 and seq % block_k == 0, (seq, block_q, block_k)
    grid = (bh, seq // block_q)
    kernel = functools.partial(_attn_kernel, block_q=block_q,
                               block_k=block_k, seq=seq, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,
    )(q, k, v, mask)
