"""Best-of-k rerank-reduce Pallas kernel (paper eq. 1's arg max).

Given a [B, K] matrix of candidate rewards and a validity mask (adaptive
allocation makes K ragged — row i only has b_i real candidates), one pass
returns the winning index and its reward. On TPU this is a lane-wise max
reduce that never leaves VMEM; fused here so the coordinator's rerank step
is a single PJRT call after reward scoring.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _rerank_kernel(s_ref, m_ref, idx_ref, val_ref):
    s = s_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    masked = jnp.where(m > 0, s, NEG_INF)
    idx_ref[...] = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    val_ref[...] = jnp.max(masked, axis=-1).astype(val_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def rerank(scores, mask, *, block_b: int = 64):
    """scores, mask: [B, K] → (best_idx int32 [B], best_val [B])."""
    b, k = scores.shape
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    return pl.pallas_call(
        _rerank_kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), scores.dtype),
        ],
        interpret=True,
    )(scores, mask)
