"""Fused RMSNorm Pallas kernel.

Normalisation is memory-bound; fusing the mean-square, rsqrt and gain into
one VMEM pass halves the HBM traffic of the naive three-op lowering. Rows
stream through in `block_r` tiles; the gain vector rides along broadcast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "eps"))
def rmsnorm(x, g, *, eps: float = 1e-6, block_r: int = 64):
    """x: [R, D]; g: [D] → [R, D] (2-D view; callers reshape)."""
    r, d = x.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, (r, block_r)
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, g)
