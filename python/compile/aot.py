"""AOT driver: train once, lower everything to HLO text, export goldens.

``python -m compile.aot --out-dir ../artifacts``  (idempotent: skips when the
source hash in artifacts/MANIFEST.json matches — ``make artifacts`` is a no-op
on an up-to-date tree).

Interchange is HLO **text** via stablehlo → XlaComputation → as_hlo_text():
xla_extension 0.5.1 (the version the rust `xla` crate binds) rejects jax≥0.5
serialized protos (64-bit instruction ids); the text parser reassigns ids.
Weights are baked into each artifact as constants (the jitted fn closes over
trained params), so the rust runtime only ever feeds activations.

Every artifact is exported twice: ``*_pallas`` (L1 kernels, interpret=True)
and ``*_xla`` (pure-jnp reference ops, XLA-fused). Numerics match to ~1e-5;
the rust benches compare the two (DESIGN.md §9).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, tasks, tokenizer, train
from .config import (ARTIFACT_BATCH, B_MAX_CHAT, DECODE_BATCH, DEFAULT_LM,
                     DEFAULT_SIZES, DEFAULT_TRAIN, MAX_SEQ, VOCAB_PADDED)

KERNEL_MODES = ("xla", "pallas")
S = MAX_SEQ
B = ARTIFACT_BATCH
DB = DECODE_BATCH

SRC_FILES = ["config.py", "tokenizer.py", "tasks.py", "data.py", "model.py",
             "train.py", "aot.py", "kernels/attention.py", "kernels/probe.py",
             "kernels/rerank.py", "kernels/rmsnorm.py", "kernels/ref.py"]


def source_hash() -> str:
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for f in SRC_FILES:
        with open(os.path.join(base, f), "rb") as fh:
            h.update(fh.read())
    return h.hexdigest()[:16]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer elides big literals as
    # `{...}`, which the rust-side HLO parser silently reads as ZEROS — the
    # baked-in weights would vanish. (Found the hard way; goldens.json now
    # guards this via `thinkalloc check`.)
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "elided constants survived the export"
    return text


def export(fn, args, path):
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec_i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--export-only", dest="export_only", action="store_true",
                    help="reuse artifacts/trained_state.pkl; skip training")
    ap.add_argument("--reuse-lm", dest="reuse_lm", action="store_true",
                    help="reuse artifacts/lm_state.pkl; retrain probes only")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "datasets"), exist_ok=True)

    manifest_path = os.path.join(out, "MANIFEST.json")
    shash = source_hash()
    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            if json.load(f).get("source_hash") == shash:
                print(f"artifacts up to date (source {shash}); skipping")
                return

    t_start = time.time()
    cfg, tc, sizes = DEFAULT_LM, DEFAULT_TRAIN, DEFAULT_SIZES
    log_lines: list[str] = []

    def log(msg):
        print(msg, flush=True)
        log_lines.append(str(msg))

    cache_path = os.path.join(out, "trained_state.pkl")
    if args.export_only and os.path.exists(cache_path):
        import pickle
        log(f"== reusing trained state from {cache_path} ==")
        with open(cache_path, "rb") as f:
            st = pickle.load(f)
        (params, lm_losses, probe_code, m_code, lora_math, probe_math, m_math,
         probe_chat, m_chat, probe_route, m_route, probe_vas, m_vas,
         reward_head, m_reward) = st
        export_all(out, shash, params, probe_code, m_code, lora_math,
                   probe_math, m_math, probe_chat, m_chat, probe_route,
                   m_route, probe_vas, m_vas, reward_head, m_reward,
                   lm_losses, log, log_lines, t_start, sizes, cfg)
        return

    # ---------------- 1. train ------------------------------------------------
    import pickle
    lm_cache = os.path.join(out, "lm_state.pkl")
    if os.path.exists(lm_cache) and (args.export_only or args.reuse_lm):
        log(f"== reusing pretrained LM from {lm_cache} ==")
        with open(lm_cache, "rb") as f:
            params, lm_losses = pickle.load(f)
    else:
        log("== pretraining TinyLM ==")
        params, lm_losses = train.pretrain_lm(tc, cfg, log=log)
        with open(lm_cache, "wb") as f:
            pickle.dump((params, lm_losses), f)

    log("== probe: code (MLP on hidden states, BCE on empirical λ) ==")
    qs_tr, ids_tr, li_tr, lam_tr = data.binary_probe_data("code", sizes.n_train, 32, 1000)
    qs_va, ids_va, li_va, lam_va = data.binary_probe_data("code", sizes.n_val, 32, 2000)
    h_tr = train.encode_all(params, ids_tr, li_tr, cfg)
    h_va = train.encode_all(params, ids_va, li_va, cfg)
    probe_code, m_code = train.train_probe(h_tr, lam_tr, h_va, lam_va,
                                           loss="bce", tc=tc, log=log, seed_offset=1)

    log("== probe: math (LoRA fine-tune variant, BCE on empirical λ) ==")
    mqs_tr, mids_tr, mli_tr, mlam_tr = data.binary_probe_data("math", sizes.n_train, 32, 1100)
    mqs_va, mids_va, mli_va, mlam_va = data.binary_probe_data("math", sizes.n_val, 32, 2100)
    lora_math, probe_math, m_math = train.train_lora_probe(
        params, mids_tr, mli_tr, mlam_tr, mids_va, mli_va, mlam_va, cfg, tc, log=log)

    log("== probe: chat Δ-vector (MSE, bootstrap targets) ==")
    cqs_tr, cids_tr, cli_tr, cd_tr = data.chat_delta_data(sizes.n_train, 64, B_MAX_CHAT, 1200)
    cqs_va, cids_va, cli_va, cd_va = data.chat_delta_data(sizes.n_val, 64, B_MAX_CHAT, 2200)
    ch_tr = train.encode_all(params, cids_tr, cli_tr, cfg, pool="mean")
    ch_va = train.encode_all(params, cids_va, cli_va, cfg, pool="mean")
    probe_chat, m_chat = train.train_probe(ch_tr, cd_tr, ch_va, cd_va,
                                           n_out=B_MAX_CHAT, loss="mse",
                                           tc=tc, log=log, seed_offset=2)

    log("== probe: routing preference (model-size pair, BCE on MC p(S≻W)) ==")
    rqs_tr, rids_tr, rli_tr, rp_tr = data.pref_probe_data(sizes.n_train, 64, 1300, vas=False)
    rqs_va, rids_va, rli_va, rp_va = data.pref_probe_data(sizes.n_val, 64, 2300, vas=False)
    rh_tr = train.encode_all(params, rids_tr, rli_tr, cfg, pool="mean")
    rh_va = train.encode_all(params, rids_va, rli_va, cfg, pool="mean")
    probe_route, m_route = train.train_probe(rh_tr, rp_tr, rh_va, rp_va,
                                             loss="bce", tc=tc, log=log, seed_offset=3)

    log("== probe: routing preference (VAS pair) ==")
    vp_tr = tasks.preference_prob(rqs_tr, 64, 1307, vas=True)
    vp_va = tasks.preference_prob(rqs_va, 64, 2307, vas=True)
    probe_vas, m_vas = train.train_probe(rh_tr, vp_tr, rh_va, vp_va,
                                         loss="bce", tc=tc, log=log, seed_offset=4)

    log("== reward head ==")
    reward_head, m_reward = train.train_reward_head(params, cfg, tc, log=log)

    with open(cache_path, "wb") as f:
        pickle.dump((params, lm_losses, probe_code, m_code, lora_math,
                     probe_math, m_math, probe_chat, m_chat, probe_route,
                     m_route, probe_vas, m_vas, reward_head, m_reward), f)

    export_all(out, shash, params, probe_code, m_code, lora_math, probe_math,
               m_math, probe_chat, m_chat, probe_route, m_route, probe_vas,
               m_vas, reward_head, m_reward, lm_losses, log, log_lines,
               t_start, sizes, cfg)


def export_all(out, shash, params, probe_code, m_code, lora_math, probe_math,
               m_math, probe_chat, m_chat, probe_route, m_route, probe_vas,
               m_vas, reward_head, m_reward, lm_losses, log, log_lines,
               t_start, sizes, cfg):
    manifest_path = os.path.join(out, "MANIFEST.json")
    # ---------------- 2. export HLO artifacts ---------------------------------
    log("== exporting HLO artifacts ==")
    written = {}

    for mode in KERNEL_MODES:
        def enc(ids, li, _m=mode):
            return (model.encode(params, ids, li, cfg, kernel_mode=_m),)

        def enc_probe_code(ids, li, _m=mode):
            h = model.encode(params, ids, li, cfg, kernel_mode=_m)
            return (model.apply_probe(probe_code, h, sigmoid=True, kernel_mode=_m)[:, 0],)

        def enc_probe_math(ids, li, _m=mode):
            h = model.encode(params, ids, li, cfg, kernel_mode=_m, lora=lora_math)
            return (model.apply_probe(probe_math, h, sigmoid=True, kernel_mode=_m)[:, 0],)

        # mean-pool heads ignore last_idx; export them single-input (XLA
        # would DCE the parameter anyway and change the runtime arity).
        def enc_probe_chat(ids, _m=mode):
            h = model.encode_mean(params, ids, None, cfg, kernel_mode=_m)
            return (model.apply_probe(probe_chat, h, sigmoid=False, kernel_mode=_m),)

        def enc_probe_route(ids, _m=mode):
            h = model.encode_mean(params, ids, None, cfg, kernel_mode=_m)
            return (model.apply_probe(probe_route, h, sigmoid=True, kernel_mode=_m)[:, 0],)

        def enc_probe_vas(ids, _m=mode):
            h = model.encode_mean(params, ids, None, cfg, kernel_mode=_m)
            return (model.apply_probe(probe_vas, h, sigmoid=True, kernel_mode=_m)[:, 0],)

        def dec_step(ids, li, _m=mode):
            return (model.decode_step(params, ids, li, cfg, kernel_mode=_m),)

        def reward_fn(ids, _m=mode):
            return (model.reward_score(params, reward_head, ids, None, cfg, kernel_mode=_m),)

        io_b = (spec_i32(B, S), spec_i32(B))
        io_b1 = (spec_i32(B, S),)
        io_db = (spec_i32(DB, S), spec_i32(DB))
        exports = [
            (f"encoder_{mode}", enc, io_b),
            (f"encode_probe_code_{mode}", enc_probe_code, io_b),
            (f"encode_probe_math_{mode}", enc_probe_math, io_b),
            (f"encode_probe_chat_{mode}", enc_probe_chat, io_b1),
            (f"encode_probe_route_{mode}", enc_probe_route, io_b1),
            (f"encode_probe_vas_{mode}", enc_probe_vas, io_b1),
            (f"decode_step_{mode}", dec_step, io_db),
            (f"reward_{mode}", reward_fn, io_b1),
        ]
        for name, fn, io in exports:
            path = os.path.join(out, name + ".hlo.txt")
            n = export(fn, io, path)
            written[name] = n
            log(f"  wrote {name}.hlo.txt ({n} chars)")

    # rerank kernel standalone (scores [B, K] → idx/val), K = B_MAX_CHAT
    from .kernels import rerank as pallas_rerank
    from .kernels.ref import ref_rerank

    for mode, fn in (("pallas", pallas_rerank), ("xla", ref_rerank)):
        name = f"rerank_{mode}"
        path = os.path.join(out, name + ".hlo.txt")
        n = export(lambda s, m, _f=fn: tuple(_f(s, m)),
                   (spec_f32(B, B_MAX_CHAT), spec_f32(B, B_MAX_CHAT)), path)
        written[name] = n
        log(f"  wrote {name}.hlo.txt ({n} chars)")

    # ---------------- 3. goldens ----------------------------------------------
    log("== goldens ==")
    rng = np.random.default_rng(7)
    g_texts = [tasks.gen_code(rng).text for _ in range(B // 2)] + \
              [tasks.gen_math(rng).text for _ in range(B // 4)] + \
              [tasks.gen_chat(rng).text for _ in range(B - B // 2 - B // 4)]
    g_ids = tokenizer.encode_batch(g_texts)
    g_li = tokenizer.last_index(g_ids)
    jid, jli = jnp.asarray(g_ids), jnp.asarray(g_li)

    h = np.asarray(model.encode(params, jid, jli, cfg))
    h_mean = np.asarray(model.encode_mean(params, jid, jli, cfg))
    lam_code = np.asarray(model.apply_probe(probe_code, jnp.asarray(h))[:, 0])
    h_lora = np.asarray(model.encode(params, jid, jli, cfg, lora=lora_math))
    lam_math = np.asarray(model.apply_probe(probe_math, jnp.asarray(h_lora))[:, 0])
    delta_chat = np.asarray(model.apply_probe(probe_chat, jnp.asarray(h_mean), sigmoid=False))
    pref_route = np.asarray(model.apply_probe(probe_route, jnp.asarray(h_mean))[:, 0])
    pref_vas = np.asarray(model.apply_probe(probe_vas, jnp.asarray(h_mean))[:, 0])
    dec_ids, dec_li = g_ids[:DB], g_li[:DB]
    dec_logits = np.asarray(model.decode_step(params, jnp.asarray(dec_ids),
                                              jnp.asarray(dec_li), cfg))
    rew = np.asarray(model.reward_score(params, reward_head, jid, jli, cfg))

    goldens = {
        "texts": g_texts,
        "ids": g_ids.tolist(),
        "last_idx": g_li.tolist(),
        "hidden_head8": h[:8, :8].tolist(),
        "lam_code": lam_code.tolist(),
        "lam_math": lam_math.tolist(),
        "delta_chat_head8": delta_chat[:8].tolist(),
        "pref_route": pref_route.tolist(),
        "pref_vas": pref_vas.tolist(),
        "decode_logits_row0_head16": dec_logits[0, :16].tolist(),
        "decode_argmax": dec_logits.argmax(axis=-1).tolist(),
        "reward": rew.tolist(),
    }
    with open(os.path.join(out, "goldens.json"), "w") as f:
        json.dump(goldens, f)

    # ---------------- 4. datasets for the rust experiment drivers -------------
    log("== exporting test datasets ==")
    def dump_queries(name, qs):
        rows = [{"text": q.text, "answer": q.answer, "lam": q.lam, "mu": q.mu,
                 "sigma": q.sigma, "gain": q.gain, "gain_vas": q.gain_vas}
                for q in qs]
        with open(os.path.join(out, "datasets", name), "w") as f:
            json.dump(rows, f)

    dump_queries("code_test.json", tasks.gen_dataset("code", sizes.n_test, 9000))
    dump_queries("math_test.json", tasks.gen_dataset("math", sizes.n_test, 9100))
    dump_queries("chat_test.json", tasks.gen_dataset("chat", sizes.n_test, 9200))

    # ---------------- 5. metrics + manifest -----------------------------------
    table1 = {"code": m_code, "math": m_math, "chat_delta": m_chat,
              "route_size": m_route, "route_vas": m_vas, "reward_head": m_reward}
    with open(os.path.join(out, "train_metrics.json"), "w") as f:
        json.dump({"table1": table1, "lm_loss_first": lm_losses[0],
                   "lm_loss_last": lm_losses[-1]}, f, indent=1)

    def tree_stats(tree, prefix=""):
        stats = {}
        if isinstance(tree, dict):
            for k, v in tree.items():
                stats.update(tree_stats(v, f"{prefix}{k}."))
        elif isinstance(tree, list):
            for i, v in enumerate(tree):
                stats.update(tree_stats(v, f"{prefix}{i}."))
        else:
            a = np.asarray(tree)
            stats[prefix[:-1]] = {"shape": list(a.shape),
                                  "norm": float(np.linalg.norm(a))}
        return stats

    manifest = {
        "source_hash": shash,
        "seq": S, "batch": B, "decode_batch": DB,
        "vocab": VOCAB_PADDED, "b_max_chat": B_MAX_CHAT,
        "artifacts": written,
        "weights": tree_stats({"lm": params, "probe_code": probe_code,
                               "probe_math": probe_math, "probe_chat": probe_chat,
                               "probe_route": probe_route, "probe_vas": probe_vas,
                               "reward_head": reward_head}),
        "elapsed_s": round(time.time() - t_start, 1),
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out, "train_log.txt"), "w") as f:
        f.write("\n".join(log_lines))
    log(f"== done in {time.time()-t_start:.1f}s ==")


if __name__ == "__main__":
    main()
