"""L2: TinyLM — the JAX compute graph the rust coordinator serves.

A decoder-only transformer used four ways (all exported as separate AOT
artifacts, weights baked in as constants):

* **encoder**      tokens → last-token hidden state (the probe's input)
* **decode step**  tokens + position → next-token logits (generation)
* **reward head**  tokens (query+response) → scalar reward
* **probe heads**  hidden → λ̂ / Δ̂-vector / p̂(S≻W)   (paper §3.1)

`kernel_mode` selects the attention/norm implementation: ``"pallas"`` lowers
the L1 kernels (interpret=True) into the artifact, ``"xla"`` uses the pure-jnp
reference ops and lets XLA fuse. Both are numerically equivalent (tested);
training always uses ``"xla"`` for speed, and the AOT step exports both so the
rust benches can compare them (DESIGN.md §9, L2 perf lever).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import TinyLMConfig, ProbeConfig, PAD_ID
from .kernels import attention as pallas_attention
from .kernels import probe_mlp as pallas_probe
from .kernels import rmsnorm as pallas_rmsnorm
from .kernels.ref import ref_attention, ref_probe_mlp, ref_rmsnorm


# --- init -------------------------------------------------------------------
def _dense(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def init_lm(key, cfg: TinyLMConfig):
    keys = jax.random.split(key, 4 + cfg.n_layers)
    params = {
        "tok_emb": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (cfg.max_seq, cfg.d_model)) * 0.02,
        "ln_f_g": jnp.ones(cfg.d_model),
        "lm_head": _dense(keys[2], cfg.d_model, cfg.vocab, 0.02),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[4 + i], 6)
        params["blocks"].append({
            "ln1_g": jnp.ones(cfg.d_model),
            "wq": _dense(ks[0], cfg.d_model, cfg.d_model),
            "wk": _dense(ks[1], cfg.d_model, cfg.d_model),
            "wv": _dense(ks[2], cfg.d_model, cfg.d_model),
            "wo": _dense(ks[3], cfg.d_model, cfg.d_model),
            "ln2_g": jnp.ones(cfg.d_model),
            "w_ff1": _dense(ks[4], cfg.d_model, cfg.d_ff),
            "b_ff1": jnp.zeros(cfg.d_ff),
            "w_ff2": _dense(ks[5], cfg.d_ff, cfg.d_model),
            "b_ff2": jnp.zeros(cfg.d_model),
        })
    return params


def init_probe(key, cfg: ProbeConfig):
    k1, k2 = jax.random.split(key)
    return {
        "w1": _dense(k1, cfg.d_in, cfg.d_hidden),
        "b1": jnp.zeros(cfg.d_hidden),
        "w2": _dense(k2, cfg.d_hidden, cfg.n_out, 0.01),
        "b2": jnp.zeros(cfg.n_out),
    }


def init_lora(key, cfg: TinyLMConfig, rank: int):
    """LoRA adapters on wq/wv of every block (paper's LoRA probe variant)."""
    out = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(jax.random.fold_in(key, i), 4)
        out.append({
            "aq": jax.random.normal(ks[0], (cfg.d_model, rank)) * 0.02,
            "bq": jnp.zeros((rank, cfg.d_model)),
            "av": jax.random.normal(ks[1], (cfg.d_model, rank)) * 0.02,
            "bv": jnp.zeros((rank, cfg.d_model)),
        })
    return out


# --- forward ----------------------------------------------------------------
def _norm(x, g, kernel_mode):
    if kernel_mode == "pallas":
        shape = x.shape
        return pallas_rmsnorm(x.reshape(-1, shape[-1]), g).reshape(shape)
    return ref_rmsnorm(x, g)


def _attn(q, k, v, mask, kernel_mode):
    if kernel_mode == "pallas":
        return pallas_attention(q, k, v, mask, causal=True)
    return ref_attention(q, k, v, mask, causal=True)


def _block(x, p, mask, cfg: TinyLMConfig, kernel_mode, lora=None):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = cfg.d_head
    y = _norm(x, p["ln1_g"], kernel_mode)
    q = y @ p["wq"]
    k = y @ p["wk"]
    v = y @ p["wv"]
    if lora is not None:
        q = q + (y @ lora["aq"]) @ lora["bq"]
        v = v + (y @ lora["av"]) @ lora["bv"]

    def split(t):  # [B,S,D] → [B*H, S, Dh]
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    mask_bh = jnp.repeat(mask, h, axis=0)
    o = _attn(split(q), split(k), split(v), mask_bh, kernel_mode)
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ p["wo"]
    y = _norm(x, p["ln2_g"], kernel_mode)
    z = y @ p["w_ff1"] + p["b_ff1"]
    z = jax.nn.gelu(z)
    return x + z @ p["w_ff2"] + p["b_ff2"]


def forward(params, ids, cfg: TinyLMConfig, *, kernel_mode="xla", lora=None):
    """ids: [B, S] int32 → hidden states [B, S, D]."""
    mask = (ids != PAD_ID).astype(jnp.float32)
    x = params["tok_emb"][ids] + params["pos_emb"][None, : ids.shape[1], :]
    x = x * mask[:, :, None]
    for i, p in enumerate(params["blocks"]):
        x = _block(x, p, mask, cfg, kernel_mode,
                   lora=None if lora is None else lora[i])
    return _norm(x, params["ln_f_g"], kernel_mode)


def logits(params, ids, cfg: TinyLMConfig, *, kernel_mode="xla", lora=None):
    """Next-token logits at every position: [B, S, V]."""
    h = forward(params, ids, cfg, kernel_mode=kernel_mode, lora=lora)
    return h @ params["lm_head"]


def encode(params, ids, last_idx, cfg: TinyLMConfig, *, kernel_mode="xla", lora=None):
    """Hidden state at the last non-PAD position: [B, D]."""
    h = forward(params, ids, cfg, kernel_mode=kernel_mode, lora=lora)
    return h[jnp.arange(ids.shape[0]), last_idx, :]


def encode_mean(params, ids, last_idx, cfg: TinyLMConfig, *, kernel_mode="xla",
                lora=None):
    """Masked mean-pooled hidden states, layer 0 ‖ final layer: [B, 2D].

    Used by the bag-affine heads (chat Δ, routing preferences, reward): their
    targets are affine in the byte bag of the text, which is *linearly*
    present in the mean of layer-0 hiddens (token+position embeddings) but
    measurably destroyed by the upper layers of this 4-layer model
    (layer-0 mean: reward linreg R² ≈ 0.8; final-layer mean: R² ≈ 0.1 —
    see DESIGN.md §Findings). Concatenating both keeps the contextual
    features the deeper probes may still want. `last_idx` is accepted for
    interface parity; pooling uses the PAD mask.
    """
    del last_idx
    mask = (ids != PAD_ID).astype(jnp.float32)
    denom = mask.sum(axis=1, keepdims=True) + 1e-6
    x0 = params["tok_emb"][ids] + params["pos_emb"][None, : ids.shape[1], :]
    pooled0 = (x0 * mask[:, :, None]).sum(axis=1) / denom
    h = forward(params, ids, cfg, kernel_mode=kernel_mode, lora=lora)
    pooled_l = (h * mask[:, :, None]).sum(axis=1) / denom
    return jnp.concatenate([pooled0, pooled_l], axis=-1)


def decode_step(params, ids, last_idx, cfg: TinyLMConfig, *, kernel_mode="xla"):
    """Logits for the token after position `last_idx`: [B, V]."""
    return encode(params, ids, last_idx, cfg, kernel_mode=kernel_mode) @ params["lm_head"]


def apply_probe(probe, h, *, sigmoid=True, kernel_mode="xla"):
    if kernel_mode == "pallas":
        return pallas_probe(h, probe["w1"], probe["b1"], probe["w2"], probe["b2"],
                            sigmoid=sigmoid)
    return ref_probe_mlp(h, probe["w1"], probe["b1"], probe["w2"], probe["b2"],
                         sigmoid=sigmoid)


def reward_score(params, head, ids, last_idx, cfg: TinyLMConfig, *, kernel_mode="xla"):
    """Scalar reward r(x,y) for full (query+response) sequences: [B].

    Mean-pooled features (the reward signal is bag-of-characters affine;
    see data.response_quality)."""
    h = encode_mean(params, ids, last_idx, cfg, kernel_mode=kernel_mode)
    return apply_probe(head, h, sigmoid=False, kernel_mode=kernel_mode)[:, 0]
