"""Byte-level tokenizer, exactly mirrored by `rust/src/tokenizer/mod.rs`.

ids 0..=255 are raw bytes; 256=PAD, 257=BOS, 258=EOS. Encoding of a query is
[BOS] + bytes + [EOS], right-padded with PAD to `max_seq`. The attention mask
marks non-PAD positions; `last_index` is the position of EOS (the hidden state
the difficulty probe reads, mirroring "last hidden state of the query").
"""

from __future__ import annotations

import numpy as np

from .config import BOS_ID, EOS_ID, MAX_SEQ, PAD_ID


def encode(text: str, max_seq: int = MAX_SEQ) -> np.ndarray:
    raw = text.encode("utf-8")
    body = list(raw[: max_seq - 2])
    ids = [BOS_ID] + body + [EOS_ID]
    ids = ids + [PAD_ID] * (max_seq - len(ids))
    return np.asarray(ids, dtype=np.int32)


def encode_batch(texts: list[str], max_seq: int = MAX_SEQ) -> np.ndarray:
    return np.stack([encode(t, max_seq) for t in texts], axis=0)


def decode(ids) -> str:
    out = bytearray()
    for i in ids:
        i = int(i)
        if i == EOS_ID:
            break
        if i < 256 and i not in (PAD_ID, BOS_ID):
            out.append(i)
    return out.decode("utf-8", errors="replace")


def mask(ids: np.ndarray) -> np.ndarray:
    """1.0 at non-PAD positions."""
    return (ids != PAD_ID).astype(np.float32)


def last_index(ids: np.ndarray) -> np.ndarray:
    """Index of the last non-PAD token (the EOS position) per row."""
    m = ids != PAD_ID
    return (m.sum(axis=-1) - 1).astype(np.int32)
