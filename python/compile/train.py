"""Build-time training of TinyLM, probe heads, LoRA variant and reward head.

Runs exactly once inside ``make artifacts`` (aot.py drives it). All training
uses the ``"xla"`` kernel mode for speed; exported artifacts may use
``"pallas"`` (numerically equivalent, tested). Optimizer is a from-scratch
Adam — no optax in the build image.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model
from .config import (DEFAULT_LM, DEFAULT_TRAIN, PAD_ID, ProbeConfig,
                     B_MAX_CHAT, TrainConfig, TinyLMConfig)


# --- Adam ---------------------------------------------------------------------
def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                                 params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# --- LM pretraining --------------------------------------------------------------
def lm_loss(params, ids, cfg: TinyLMConfig):
    """Next-token cross entropy; PAD targets masked out."""
    lg = model.logits(params, ids[:, :-1], cfg)
    tgt = ids[:, 1:]
    mask = (tgt != PAD_ID).astype(jnp.float32)
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / (mask.sum() + 1e-9)


def pretrain_lm(tc: TrainConfig = DEFAULT_TRAIN, cfg: TinyLMConfig = DEFAULT_LM,
                log=print):
    key = jax.random.PRNGKey(tc.seed)
    params = model.init_lm(key, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, ids, lr):
        loss, grads = jax.value_and_grad(lm_loss)(params, ids, cfg)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    losses = []
    t0 = time.time()
    batches = data.corpus_batches(60000, tc.lm_batch, tc.lm_steps, tc.seed + 100)
    for i, ids in enumerate(batches):
        # linear warmup → cosine decay to 10% (a flat lr plateaus ~1.9 and
        # the model never gets past format-learning into task-learning)
        warm = min(1.0, (i + 1) / max(tc.lm_warmup, 1))
        progress = i / max(tc.lm_steps - 1, 1)
        decay = 0.1 + 0.9 * 0.5 * (1.0 + np.cos(np.pi * progress))
        params, opt, loss = step(params, opt, jnp.asarray(ids), tc.lm_lr * warm * decay)
        losses.append(float(loss))
        if i % 100 == 0:
            log(f"  lm step {i:4d} loss {float(loss):.4f}")
    log(f"  lm pretrain done in {time.time()-t0:.1f}s, "
        f"loss {losses[0]:.3f} → {losses[-1]:.3f}")
    return params, losses


# --- hidden-state caching ----------------------------------------------------------
def encode_all(params, ids, last_idx, cfg: TinyLMConfig, batch=256, lora=None,
               pool="last"):
    """Encode a full dataset to hidden states, batched.

    pool="last" → EOS-position hidden (code/math λ heads, reward head);
    pool="mean" → masked mean-pooled hidden (chat/routing heads).
    """
    enc_fn = model.encode if pool == "last" else model.encode_mean
    enc = jax.jit(lambda i, li: enc_fn(params, i, li, cfg, lora=lora))
    outs = []
    n = ids.shape[0]
    for s in range(0, n, batch):
        outs.append(np.asarray(enc(jnp.asarray(ids[s:s + batch]),
                                   jnp.asarray(last_idx[s:s + batch]))))
    return np.concatenate(outs, axis=0)


# --- probe head training ------------------------------------------------------------
def bce(pred, target):
    pred = jnp.clip(pred, 1e-6, 1 - 1e-6)
    return -(target * jnp.log(pred) + (1 - target) * jnp.log(1 - pred)).mean()


def train_probe(h_train, y_train, h_val, y_val, *, n_out=1, loss="bce",
                tc: TrainConfig = DEFAULT_TRAIN, log=print, seed_offset=0):
    """Train an MLP probe head on cached hidden states.

    loss: "bce" for λ/preference heads (soft targets), "mse" for Δ vectors.
    Returns (probe_params, metrics dict with train/val losses + Table-1 stats).
    """
    pc = ProbeConfig(d_in=h_train.shape[1], n_out=n_out)
    probe = model.init_probe(jax.random.PRNGKey(tc.seed + 17 + seed_offset), pc)
    opt = adam_init(probe)
    sigmoid = loss == "bce"

    # Standardize features; the constants are folded back into (w1, b1) after
    # training so the exported probe remains a plain MLP on raw hidden states:
    #   (h−μ)/σ·W1 + b1  ≡  h·(W1/σ) + (b1 − (μ/σ)·W1)
    feat_mu = h_train.mean(axis=0)
    feat_sd = h_train.std(axis=0)
    # dead/near-constant dims would explode under 1/σ — leave them unscaled
    feat_sd = np.where(feat_sd < 1e-4, 1.0, feat_sd)
    h_train = (h_train - feat_mu) / feat_sd
    h_val_n = (h_val - feat_mu) / feat_sd

    def loss_fn(probe, h, y):
        out = model.apply_probe(probe, h, sigmoid=sigmoid)
        out = out[:, 0] if n_out == 1 else out
        return bce(out, y) if loss == "bce" else ((out - y) ** 2).mean()

    @jax.jit
    def step(probe, opt, h, y):
        lval, grads = jax.value_and_grad(loss_fn)(probe, h, y)
        probe, opt = adam_update(probe, grads, opt, tc.probe_lr)
        return probe, opt, lval

    rng = np.random.default_rng(tc.seed + 23 + seed_offset)
    n = h_train.shape[0]
    for i in range(tc.probe_steps):
        sel = rng.integers(0, n, tc.probe_batch)
        probe, opt, lval = step(probe, opt, jnp.asarray(h_train[sel]),
                                jnp.asarray(y_train[sel]))
        if i % 200 == 0:
            log(f"  probe step {i:4d} loss {float(lval):.4f}")

    val_loss = float(loss_fn(probe, jnp.asarray(h_val_n), jnp.asarray(y_val)))
    # fold the standardization into the first layer (see above)
    w1 = np.asarray(probe["w1"]) / feat_sd[:, None]
    b1 = np.asarray(probe["b1"]) - (feat_mu / feat_sd) @ np.asarray(probe["w1"])
    probe = {**probe, "w1": jnp.asarray(w1), "b1": jnp.asarray(b1)}
    fold_check = float(loss_fn(probe, jnp.asarray(h_val), jnp.asarray(y_val)))
    assert abs(fold_check - val_loss) < 1e-3, (fold_check, val_loss)
    metrics = {"val_loss": val_loss}
    if loss == "bce" and n_out == 1:
        # Table-1 companions: Avg. baseline, Opt.* oracle loss, median accuracy.
        ybar = float(np.clip(y_val.mean(), 1e-6, 1 - 1e-6))
        metrics["avg_loss"] = float(
            -(y_val * np.log(ybar) + (1 - y_val) * np.log(1 - ybar)).mean())
        yc = np.clip(y_val, 1e-6, 1 - 1e-6)
        metrics["opt_loss"] = float(
            -(y_val * np.log(yc) + (1 - y_val) * np.log(1 - yc)).mean())
        pred = np.asarray(model.apply_probe(probe, jnp.asarray(h_val)))[:, 0]
        # Paper's Acc: median-split labels. Threshold predictions at *their*
        # median (rank-based) — thresholding sigmoid outputs at a label
        # median of exactly 0 (code's λ=0 mass) is degenerate.
        metrics["acc"] = float(
            ((pred > np.median(pred)) == (y_val > np.median(y_val))).mean())
    if loss == "mse":
        yv = np.atleast_2d(np.asarray(y_val)) if np.ndim(y_val) == 1 else y_val
        yv = yv.reshape(len(h_val), -1)
        ybar = yv.mean(axis=0, keepdims=True)
        metrics["avg_loss"] = float(((yv - ybar) ** 2).mean())
        metrics["opt_loss"] = 0.0
        pred = np.asarray(model.apply_probe(probe, jnp.asarray(h_val), sigmoid=False))
        metrics["acc"] = float(((pred[:, 0] > np.median(pred[:, 0]))
                                == (yv[:, 0] > np.median(yv[:, 0]))).mean())
    log(f"  probe val_loss {val_loss:.4f} metrics {metrics}")
    return probe, metrics


# --- LoRA fine-tune (math variant) ---------------------------------------------------
def train_lora_probe(params, ids_tr, li_tr, y_tr, ids_va, li_va, y_va,
                     cfg: TinyLMConfig = DEFAULT_LM,
                     tc: TrainConfig = DEFAULT_TRAIN, log=print):
    """Jointly train LoRA adapters + λ head (paper's LoRA probe variant)."""
    key = jax.random.PRNGKey(tc.seed + 31)
    lora = model.init_lora(key, cfg, tc.lora_rank)
    pc = ProbeConfig(d_in=cfg.d_model, n_out=1)
    probe = model.init_probe(jax.random.fold_in(key, 1), pc)
    trainable = {"lora": lora, "probe": probe}
    opt = adam_init(trainable)

    def loss_fn(tr, ids, li, y):
        h = model.encode(params, ids, li, cfg, lora=tr["lora"])
        lam = model.apply_probe(tr["probe"], h, sigmoid=True)[:, 0]
        return bce(lam, y)

    @jax.jit
    def step(tr, opt, ids, li, y):
        lval, grads = jax.value_and_grad(loss_fn)(tr, ids, li, y)
        tr, opt = adam_update(tr, grads, opt, tc.lora_lr)
        return tr, opt, lval

    rng = np.random.default_rng(tc.seed + 37)
    n = ids_tr.shape[0]
    bs = 64
    for i in range(tc.lora_steps):
        sel = rng.integers(0, n, bs)
        trainable, opt, lval = step(trainable, opt, jnp.asarray(ids_tr[sel]),
                                    jnp.asarray(li_tr[sel]), jnp.asarray(y_tr[sel]))
        if i % 50 == 0:
            log(f"  lora step {i:4d} loss {float(lval):.4f}")

    val_loss = float(loss_fn(trainable, jnp.asarray(ids_va),
                             jnp.asarray(li_va), jnp.asarray(y_va)))
    ybar = float(np.clip(y_va.mean(), 1e-6, 1 - 1e-6))
    yc = np.clip(y_va, 1e-6, 1 - 1e-6)
    h_va = encode_all(params, ids_va, li_va, cfg, lora=trainable["lora"])
    pred = np.asarray(model.apply_probe(trainable["probe"], jnp.asarray(h_va)))[:, 0]
    metrics = {
        "val_loss": val_loss,
        "avg_loss": float(-(y_va * np.log(ybar) + (1 - y_va) * np.log(1 - ybar)).mean()),
        "opt_loss": float(-(y_va * np.log(yc) + (1 - y_va) * np.log(1 - yc)).mean()),
        "acc": float(((pred > np.median(pred)) == (y_va > np.median(y_va))).mean()),
    }
    log(f"  lora val_loss {val_loss:.4f} metrics {metrics}")
    return trainable["lora"], trainable["probe"], metrics


# --- reward head ------------------------------------------------------------------------
def train_reward_head(params, cfg: TinyLMConfig = DEFAULT_LM,
                      tc: TrainConfig = DEFAULT_TRAIN, log=print):
    """Reward head r̂(x,y): an MSE probe on mean-pooled hidden states of the
    full `query = response` string (reuses train_probe's standardization)."""
    ids, li, r = data.reward_head_data(4096, tc.seed + 41)
    h = encode_all(params, ids, li, cfg, pool="mean")
    n_val = 512
    head, metrics = train_probe(h[n_val:], r[n_val:], h[:n_val], r[:n_val],
                                n_out=1, loss="mse", tc=tc, log=log,
                                seed_offset=9)
    out = {"mse": metrics["val_loss"], "target_var": float(r.var()),
           "avg_loss": metrics["avg_loss"]}
    log(f"  reward head mse {out['mse']:.4f} (target var {out['target_var']:.4f})")
    return head, out
