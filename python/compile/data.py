"""Dataset assembly for build-time training (L2).

Turns the synthetic task universe (tasks.py) into:
* next-token pretraining batches for TinyLM,
* (hidden-state, target) supervision for every probe head — empirical λ̂ for
  binary domains (paper §3.3), bootstrap Δ̂ vectors for chat (paper eq. 6),
  Monte-Carlo preference probabilities for routing (paper eq. 11/12),
* (tokens, reward) pairs for the reward head.

The best-of-k expectation uses the classic unbiased order-statistic estimator
E[max of j draws] = Σ_i C(i−1, j−1)/C(m, j) · r_(i) over m observed rewards —
the same estimator implemented in ``rust/src/simulator/bootstrap.rs`` and
cross-checked by goldens.
"""

from __future__ import annotations

import numpy as np

from . import tasks, tokenizer
from .config import MAX_SEQ


# --- unbiased best-of-k curve -------------------------------------------------
def best_of_k_curve(rewards: np.ndarray, k_max: int) -> np.ndarray:
    """E[max of j samples] for j=1..k_max from m observed rewards (unbiased).

    rewards: [m] → [k_max]. Requires k_max <= m.
    """
    m = rewards.shape[0]
    assert k_max <= m, (k_max, m)
    r = np.sort(rewards)
    out = np.empty(k_max, dtype=np.float64)
    for j in range(1, k_max + 1):
        # w_i = C(i-1, j-1) / C(m, j) for i = j..m, by stable recurrence
        # C(i, j-1) = C(i-1, j-1) * i / (i - j + 1).
        denom = 1.0
        for t in range(j):  # C(m, j)
            denom *= (m - t) / (t + 1)
        w = np.zeros(m)
        c = 1.0  # C(j-1, j-1)
        for i in range(j, m + 1):
            w[i - 1] = c / denom
            c *= i / (i - j + 1)
        out[j - 1] = float((w * r).sum())
    return out.astype(np.float32)


def marginal_rewards(rewards: np.ndarray, k_max: int) -> np.ndarray:
    """Δ_j = E[max_j] − E[max_{j−1}], with E[max_0] = 0 (paper §3)."""
    q = best_of_k_curve(rewards, k_max)
    d = np.empty_like(q)
    d[0] = q[0]
    d[1:] = q[1:] - q[:-1]
    return d


# --- LM pretraining batches ---------------------------------------------------
def corpus_batches(n_lines: int, batch: int, steps: int, seed: int):
    """Yield (ids [B,S], valid-target mask [B,S]) pretraining batches."""
    lines = tasks.gen_corpus(n_lines, seed)
    ids = tokenizer.encode_batch(lines)
    rng = np.random.default_rng(seed + 1)
    for _ in range(steps):
        sel = rng.integers(0, len(lines), batch)
        yield ids[sel]


# --- probe supervision ----------------------------------------------------------
def binary_probe_data(domain: str, n: int, m_samples: int, seed: int):
    """(queries, ids, last_idx, λ̂_emp [n]) for code/math λ heads."""
    qs = tasks.gen_dataset(domain, n, seed)
    outcomes = tasks.sample_binary_outcomes(qs, m_samples, seed + 7)
    lam_emp = outcomes.mean(axis=1).astype(np.float32)
    ids = tokenizer.encode_batch([q.text for q in qs])
    return qs, ids, tokenizer.last_index(ids), lam_emp


def chat_delta_data(n: int, m_samples: int, k_max: int, seed: int):
    """(queries, ids, last_idx, Δ̂ [n, k_max]) for the chat MSE head."""
    qs = tasks.gen_dataset("chat", n, seed)
    rewards = tasks.sample_chat_rewards(qs, m_samples, seed + 7)
    deltas = np.stack([marginal_rewards(rewards[i], k_max)
                       for i in range(n)], axis=0)
    ids = tokenizer.encode_batch([q.text for q in qs])
    return qs, ids, tokenizer.last_index(ids), deltas.astype(np.float32)


def pref_probe_data(n: int, n_mc: int, seed: int, vas: bool):
    """(queries, ids, last_idx, p̂(S≻W) [n]) for routing heads."""
    qs = tasks.gen_dataset("chat", n, seed)
    pref = tasks.preference_prob(qs, n_mc, seed + 7, vas=vas)
    ids = tokenizer.encode_batch([q.text for q in qs])
    return qs, ids, tokenizer.last_index(ids), pref


# --- reward-head supervision -----------------------------------------------------
def response_quality(resp: str) -> float:
    """Deterministic response quality feature, mirrored in rust/src/workload.

    Mean chat-weight of the response's alphabet characters: responses made of
    "good" words score higher. Linear in the byte bag, so the reward head
    (an MLP on mean-pooled hidden states) can actually learn it — an earlier
    modular-hash definition was unlearnable by construction.
    """
    idx = [tasks.CHAT_ALPHABET.index(c) for c in resp if c in tasks.CHAT_ALPHABET]
    if not idx:
        return -0.5
    return float(sum(tasks.chat_weight(i) for i in idx) / len(idx))


def true_reward(q: tasks.Query, resp: str) -> float:
    """Ground-truth reward the reward head is trained to approximate."""
    return q.mu + 0.8 * response_quality(resp)


def reward_head_data(n: int, seed: int):
    """(ids, last_idx, r) over chat query+response strings."""
    rng = np.random.default_rng(seed)
    qs = tasks.gen_dataset("chat", n, seed)
    rows, targets = [], []
    for q in qs:
        m = int(rng.integers(1, 7))
        words = [tasks.CHAT_WORDS[int(rng.integers(0, 64))] for _ in range(m)]
        resp = " ".join(words)
        full = q.text + " = " + resp
        if len(full.encode()) > MAX_SEQ - 2:
            full = full[: MAX_SEQ - 2]
            resp = full.split(" = ", 1)[1] if " = " in full else resp
        rows.append(full)
        targets.append(true_reward(q, resp))
    ids = tokenizer.encode_batch(rows)
    return ids, tokenizer.last_index(ids), np.asarray(targets, dtype=np.float32)
