"""Training smoke: Adam works, LM loss falls, probes learn separable signal."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train
from compile.config import TinyLMConfig, TrainConfig

CFG = TinyLMConfig(n_layers=2)
TC = TrainConfig(lm_steps=30, probe_steps=150, reward_steps=10, lora_steps=6)


def test_adam_descends_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, opt = train.adam_update(params, grads, opt, lr=0.05)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_lm_loss_decreases():
    params, losses = train.pretrain_lm(TC, CFG, log=lambda *_: None)
    assert losses[-1] < losses[0] - 0.3


def test_probe_learns_separable():
    """Probe must fit a linearly-separable difficulty signal quickly."""
    rng = np.random.default_rng(0)
    h = rng.normal(size=(512, 32)).astype(np.float32)
    y = (1 / (1 + np.exp(-3 * h[:, 0]))).astype(np.float32)  # soft labels
    probe, m = train.train_probe(h[:384], y[:384], h[384:], y[384:],
                                 loss="bce", tc=TC, log=lambda *_: None)
    assert m["val_loss"] < m["avg_loss"] - 0.05
    assert m["acc"] > 0.8


def test_probe_mse_vector_head():
    rng = np.random.default_rng(1)
    h = rng.normal(size=(512, 16)).astype(np.float32)
    w = rng.normal(size=(16, 4)).astype(np.float32)
    y = (h @ w * 0.1).astype(np.float32)
    probe, m = train.train_probe(h[:384], y[:384], h[384:], y[384:],
                                 n_out=4, loss="mse", tc=TC, log=lambda *_: None)
    assert m["val_loss"] < m["avg_loss"] * 0.6


def test_bce_soft_labels():
    p = jnp.asarray([0.3, 0.7])
    t = jnp.asarray([0.3, 0.7])
    perfect = float(train.bce(p, t))
    off = float(train.bce(jnp.asarray([0.9, 0.1]), t))
    assert perfect < off
