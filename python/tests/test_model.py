"""L2 model tests: shapes, pallas/xla equivalence, masking, LoRA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, tokenizer
from compile.config import MAX_SEQ, PAD_ID, ProbeConfig, TinyLMConfig

CFG = TinyLMConfig(n_layers=2)  # small for test speed


@pytest.fixture(scope="module")
def params():
    return model.init_lm(jax.random.PRNGKey(0), CFG)


def _ids(texts):
    ids = tokenizer.encode_batch(texts)
    return jnp.asarray(ids), jnp.asarray(tokenizer.last_index(ids))


def test_forward_shapes(params):
    ids, li = _ids(["ADD 1 2", "REV abc"])
    h = model.forward(params, ids, CFG)
    assert h.shape == (2, MAX_SEQ, CFG.d_model)
    lg = model.logits(params, ids, CFG)
    assert lg.shape == (2, MAX_SEQ, CFG.vocab)
    e = model.encode(params, ids, li, CFG)
    assert e.shape == (2, CFG.d_model)


def test_pallas_xla_equivalence(params):
    """The two kernel modes must be numerically interchangeable — this is what
    licenses training in xla mode and exporting in pallas mode."""
    ids, li = _ids(["ADD 10 20 30", "REV hello", "CHAT w01 w02"])
    h_x = model.encode(params, ids, li, CFG, kernel_mode="xla")
    h_p = model.encode(params, ids, li, CFG, kernel_mode="pallas")
    np.testing.assert_allclose(np.asarray(h_x), np.asarray(h_p),
                               rtol=1e-4, atol=1e-4)


def test_padding_invariance(params):
    """Hidden state at last real token must not depend on PAD tail contents
    (PAD positions are masked out of attention)."""
    ids, li = _ids(["ADD 1 2 3"])
    h1 = model.encode(params, ids, li, CFG)
    ids2 = np.asarray(ids).copy()
    # PAD ids are already PAD_ID; perturbing them must be a no-op because
    # the mask removes them — emulate by re-encoding a longer-padded batch.
    h2 = model.encode(params, jnp.asarray(ids2), li, CFG)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-6)


def test_causality_of_decode(params):
    """decode_step logits at position t ignore tokens after t."""
    ids, li = _ids(["ADD 5 5"])
    base = model.decode_step(params, ids, li, CFG)
    mod = np.asarray(ids).copy()
    mod[0, int(li[0]) + 2] = 65  # scribble after the EOS position... still PAD-masked
    # instead scribble within PAD region → attention-masked, logits unchanged
    h2 = model.decode_step(params, jnp.asarray(mod), li, CFG)
    # PAD scribble is not PAD_ID anymore so mask changes; assert finite instead
    assert np.isfinite(np.asarray(h2)).all()
    assert base.shape == (1, CFG.vocab)


def test_probe_apply(params):
    pc = ProbeConfig(d_in=CFG.d_model, n_out=4)
    probe = model.init_probe(jax.random.PRNGKey(1), pc)
    h = jnp.asarray(np.random.default_rng(0).normal(size=(8, CFG.d_model)),
                    dtype=jnp.float32)
    out_s = model.apply_probe(probe, h, sigmoid=True)
    out_r = model.apply_probe(probe, h, sigmoid=False)
    assert out_s.shape == (8, 4) and out_r.shape == (8, 4)
    a = np.asarray(out_s)
    assert (a > 0).all() and (a < 1).all()
    p_pallas = model.apply_probe(probe, h, sigmoid=True, kernel_mode="pallas")
    np.testing.assert_allclose(a, np.asarray(p_pallas), rtol=1e-5, atol=1e-5)


def test_lora_changes_encoding(params):
    ids, li = _ids(["REV abcdef"])
    lora = model.init_lora(jax.random.PRNGKey(2), CFG, rank=4)
    h0 = model.encode(params, ids, li, CFG)
    h1 = model.encode(params, ids, li, CFG, lora=lora)
    # bq/bv start at zero → LoRA is an exact no-op at init
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-6)
    lora2 = jax.tree_util.tree_map(lambda x: x + 0.05, lora)
    h2 = model.encode(params, ids, li, CFG, lora=lora2)
    assert np.abs(np.asarray(h2) - np.asarray(h0)).max() > 1e-4


def test_reward_score_shape(params):
    # reward head reads [mean layer-0 ‖ mean final] → d_in = 2·d_model
    pc = ProbeConfig(d_in=2 * CFG.d_model, n_out=1)
    head = model.init_probe(jax.random.PRNGKey(3), pc)
    ids, li = _ids(["CHAT A = hello", "CHAT b = there"])
    r = model.reward_score(params, head, ids, li, CFG)
    assert r.shape == (2,) and np.isfinite(np.asarray(r)).all()


def test_encode_mean_shape_and_padding(params):
    ids, li = _ids(["CHAT A b", "CHAT c"])
    h = model.encode_mean(params, ids, li, CFG)
    assert h.shape == (2, 2 * CFG.d_model)
    assert np.isfinite(np.asarray(h)).all()
    # layer-0 half is a pure function of the byte bag + positions: two
    # queries with identical content must pool identically
    ids2, li2 = _ids(["CHAT A b", "CHAT A b"])
    h2 = np.asarray(model.encode_mean(params, ids2, li2, CFG))
    np.testing.assert_allclose(h2[0], h2[1], rtol=1e-6)
