"""AOT artifact checks (skipped until `make artifacts` has run)."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "MANIFEST.json")),
    reason="artifacts not built (run `make artifacts`)")


def manifest():
    with open(os.path.join(ART, "MANIFEST.json")) as f:
        return json.load(f)


def test_all_artifacts_present():
    m = manifest()
    for name, n_chars in m["artifacts"].items():
        p = os.path.join(ART, name + ".hlo.txt")
        assert os.path.exists(p), name
        assert os.path.getsize(p) == n_chars


def test_hlo_text_headers():
    m = manifest()
    for name in m["artifacts"]:
        with open(os.path.join(ART, name + ".hlo.txt")) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), name
        assert "entry_computation_layout" in head, name


def test_pallas_and_xla_variants_both_exported():
    m = manifest()
    bases = {n.rsplit("_", 1)[0] for n in m["artifacts"]}
    for base in bases:
        assert f"{base}_xla" in m["artifacts"], base
        assert f"{base}_pallas" in m["artifacts"], base


def test_goldens_consistency():
    with open(os.path.join(ART, "goldens.json")) as f:
        g = json.load(f)
    lam = np.asarray(g["lam_code"])
    assert ((lam > 0) & (lam < 1)).all()
    pref = np.asarray(g["pref_route"])
    assert ((pref > 0) & (pref < 1)).all()
    ids = np.asarray(g["ids"])
    assert ids.shape[1] == manifest()["seq"]


def test_datasets_exported():
    for name in ("code_test.json", "math_test.json", "chat_test.json"):
        with open(os.path.join(ART, "datasets", name)) as f:
            rows = json.load(f)
        assert len(rows) >= 1000
        assert {"text", "lam", "mu", "sigma", "gain", "gain_vas"} <= set(rows[0])


def test_probe_beats_avg_baseline():
    """Table-1 property: learned probes beat the constant-prediction baseline."""
    with open(os.path.join(ART, "train_metrics.json")) as f:
        t1 = json.load(f)["table1"]
    for setting in ("code", "math"):
        assert t1[setting]["val_loss"] < t1[setting]["avg_loss"], setting
        assert t1[setting]["acc"] > 0.6, setting
