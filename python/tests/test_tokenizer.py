"""Tokenizer contract tests — must stay in lockstep with rust/src/tokenizer."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import tokenizer
from compile.config import BOS_ID, EOS_ID, MAX_SEQ, PAD_ID

ascii_text = st.text(
    alphabet=st.characters(min_codepoint=32, max_codepoint=126), max_size=80)


@settings(deadline=None, max_examples=50)
@given(ascii_text)
def test_roundtrip(s):
    ids = tokenizer.encode(s)
    assert ids.shape == (MAX_SEQ,)
    assert ids[0] == BOS_ID
    assert tokenizer.decode(ids) == s[: MAX_SEQ - 2]


@settings(deadline=None, max_examples=30)
@given(ascii_text)
def test_mask_and_last_index(s):
    ids = tokenizer.encode(s)
    m = tokenizer.mask(ids)
    li = int(tokenizer.last_index(ids))
    body = len(s.encode()[: MAX_SEQ - 2])
    assert m.sum() == body + 2
    assert ids[li] == EOS_ID
    assert (ids[li + 1:] == PAD_ID).all()


def test_truncation():
    s = "x" * 200
    ids = tokenizer.encode(s)
    assert ids[0] == BOS_ID and ids[-1] == EOS_ID
    assert (ids != PAD_ID).all()


def test_batch_shapes():
    b = tokenizer.encode_batch(["a", "bb", "ccc"])
    assert b.shape == (3, MAX_SEQ) and b.dtype == np.int32
