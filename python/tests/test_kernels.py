"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/seeds/block sizes; assert_allclose against ref.py.
This is the core correctness signal for what gets lowered into the AOT
artifacts the rust runtime serves.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, probe_mlp, rerank, rmsnorm
from compile.kernels.ref import (ref_attention, ref_probe_mlp, ref_rerank,
                                 ref_rmsnorm)

TOL = dict(rtol=2e-5, atol=2e-5)


def rnd(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# --- attention ----------------------------------------------------------------
@settings(deadline=None, max_examples=12)
@given(
    bh=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 10_000),
)
def test_attention_matches_ref(bh, seq, d, block, seed):
    if seq % block != 0:
        return
    rng = np.random.default_rng(seed)
    q, k, v = (rnd(rng, bh, seq, d) for _ in range(3))
    mask = jnp.asarray((rng.random((bh, seq)) < 0.85).astype(np.float32))
    mask = mask.at[:, 0].set(1.0)  # ensure at least one valid key
    out = attention(q, k, v, mask, block_q=block, block_k=block)
    ref = ref_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_attention_causality():
    """Changing a future token must not change past outputs."""
    rng = np.random.default_rng(0)
    q, k, v = (rnd(rng, 2, 32, 16) for _ in range(3))
    mask = jnp.ones((2, 32))
    base = np.asarray(attention(q, k, v, mask))
    k2 = k.at[:, 20:, :].set(0.0)
    v2 = v.at[:, 20:, :].set(0.0)
    pert = np.asarray(attention(q, k2, v2, mask))
    np.testing.assert_allclose(base[:, :20], pert[:, :20], **TOL)
    assert np.abs(base[:, 20:] - pert[:, 20:]).max() > 1e-4


def test_attention_fully_padded_rows_finite():
    rng = np.random.default_rng(1)
    q, k, v = (rnd(rng, 1, 16, 8) for _ in range(3))
    mask = jnp.zeros((1, 16)).at[:, 0].set(1.0)
    out = np.asarray(attention(q, k, v, mask))
    assert np.isfinite(out).all()


# --- probe MLP ----------------------------------------------------------------
@settings(deadline=None, max_examples=15)
@given(
    b=st.sampled_from([8, 32, 64, 128]),
    d=st.sampled_from([16, 64, 128]),
    h=st.sampled_from([32, 128]),
    o=st.sampled_from([1, 4, 8]),
    sigmoid=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_probe_matches_ref(b, d, h, o, sigmoid, seed):
    rng = np.random.default_rng(seed)
    hx = rnd(rng, b, d)
    w1, b1 = rnd(rng, d, h) * 0.2, rnd(rng, h) * 0.1
    w2, b2 = rnd(rng, h, o) * 0.2, rnd(rng, o) * 0.1
    out = probe_mlp(hx, w1, b1, w2, b2, sigmoid=sigmoid, block_b=min(32, b))
    ref = ref_probe_mlp(hx, w1, b1, w2, b2, sigmoid=sigmoid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_probe_sigmoid_bounds():
    rng = np.random.default_rng(3)
    out = probe_mlp(rnd(rng, 16, 8) * 10, rnd(rng, 8, 8), rnd(rng, 8),
                    rnd(rng, 8, 2), rnd(rng, 2), sigmoid=True)
    a = np.asarray(out)
    # f32 sigmoid may saturate to exactly 0/1 on extreme logits
    assert (a >= 0).all() and (a <= 1).all() and np.isfinite(a).all()


# --- rerank --------------------------------------------------------------------
@settings(deadline=None, max_examples=15)
@given(
    b=st.sampled_from([8, 64, 128]),
    k=st.sampled_from([1, 4, 8, 100]),
    seed=st.integers(0, 10_000),
)
def test_rerank_matches_ref(b, k, seed):
    rng = np.random.default_rng(seed)
    s = rnd(rng, b, k)
    m = jnp.asarray((rng.random((b, k)) < 0.6).astype(np.float32))
    i1, v1 = rerank(s, m, block_b=min(32, b))
    i2, v2 = ref_rerank(s, m)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), **TOL)


def test_rerank_respects_mask():
    s = jnp.asarray([[5.0, 1.0, 3.0]])
    m = jnp.asarray([[0.0, 1.0, 1.0]])  # best raw score is masked out
    i, v = rerank(s, m)
    assert int(i[0]) == 2 and abs(float(v[0]) - 3.0) < 1e-6


def test_rerank_all_masked():
    s = jnp.asarray([[5.0, 1.0]])
    m = jnp.zeros((1, 2))
    i, v = rerank(s, m)
    assert float(v[0]) < -1e29


# --- rmsnorm ---------------------------------------------------------------------
@settings(deadline=None, max_examples=12)
@given(
    r=st.sampled_from([8, 64, 256]),
    d=st.sampled_from([16, 128]),
    seed=st.integers(0, 10_000),
)
def test_rmsnorm_matches_ref(r, d, seed):
    rng = np.random.default_rng(seed)
    x, g = rnd(rng, r, d), rnd(rng, d)
    out = rmsnorm(x, g, block_r=min(64, r))
    ref = ref_rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_rmsnorm_unit_rms():
    rng = np.random.default_rng(5)
    x = rnd(rng, 32, 64)
    out = np.asarray(rmsnorm(x, jnp.ones(64)))
    rms = np.sqrt((out ** 2).mean(axis=-1))
    np.testing.assert_allclose(rms, np.ones(32), rtol=1e-3, atol=1e-3)
