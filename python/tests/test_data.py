"""Best-of-k order-statistic estimator + probe dataset assembly."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data, tasks


@settings(deadline=None, max_examples=20)
@given(st.floats(0.05, 0.95), st.integers(0, 1000))
def test_curve_matches_analytic_binary(p, seed):
    """For Bernoulli rewards, E[max_j] = 1 − (1−λ)^j (paper §3.3)."""
    rng = np.random.default_rng(seed)
    r = (rng.random(3000) < p).astype(np.float64)
    q = data.best_of_k_curve(r, 10)
    lam = r.mean()
    anal = 1 - (1 - lam) ** np.arange(1, 11)
    np.testing.assert_allclose(q, anal, atol=5e-3)


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 1000))
def test_curve_monotone_nondecreasing(seed):
    rng = np.random.default_rng(seed)
    r = rng.normal(size=64)
    q = data.best_of_k_curve(r, 32)
    assert (np.diff(q) >= -1e-9).all()
    assert abs(q[0] - r.mean()) < 1e-6  # E[max_1] = mean


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 1000))
def test_marginals_nonincreasing(seed):
    """Δ_j is non-increasing for j ≥ 2 (concavity of E[max_j]; diminishing
    returns is what makes the paper's greedy allocation optimal). Δ_1 is
    anchored at q(·,0)=0 so it can sit below Δ_2 when rewards are negative —
    which is exactly why the paper forces b_i ≥ 1 in the chat setting."""
    rng = np.random.default_rng(seed)
    r = rng.normal(size=64)
    d = data.marginal_rewards(r, 32)
    assert (np.diff(d[1:]) <= 1e-9).all()
    # with nonnegative rewards the full vector is monotone
    d2 = data.marginal_rewards(np.abs(r), 32)
    assert (np.diff(d2) <= 1e-6).all()


def test_curve_kmax_equals_m():
    r = np.asarray([1.0, 2.0, 3.0])
    q = data.best_of_k_curve(r, 3)
    assert abs(q[2] - 3.0) < 1e-9  # E[max of m draws w/o replacement] = max


def test_binary_probe_data_shapes():
    qs, ids, li, lam = data.binary_probe_data("code", 64, 16, 0)
    assert ids.shape == (64, 64) and li.shape == (64,) and lam.shape == (64,)
    assert ((lam >= 0) & (lam <= 1)).all()


def test_chat_delta_targets():
    qs, ids, li, d = data.chat_delta_data(32, 64, 8, 0)
    assert d.shape == (32, 8)
    assert (np.diff(d[:, 1:], axis=1) <= 1e-6).all()  # Δ_2.. non-increasing
    mu = np.asarray([q.mu for q in qs])
    np.testing.assert_allclose(d[:, 0], mu, atol=0.5)  # Δ_1 = E[r] ≈ μ


def test_pref_probe_data():
    qs, ids, li, p = data.pref_probe_data(64, 32, 0, vas=False)
    assert ((p > 0) & (p < 1)).all()


def test_response_quality_deterministic():
    assert data.response_quality("abc") == data.response_quality("abc")
    assert -0.6 <= data.response_quality("hello world") <= 0.6
    assert data.response_quality("") == -0.5
    # single alphabet char: exactly its chat weight
    assert data.response_quality("A") == tasks.chat_weight(0)


def test_reward_head_data():
    ids, li, r = data.reward_head_data(64, 0)
    assert ids.shape[0] == 64 and np.isfinite(r).all()
