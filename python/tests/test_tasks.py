"""Synthetic task universe: distributional properties the paper's figures need,
plus determinism contracts for the rust mirror."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import tasks


def test_code_zero_mass_near_half():
    """Fig. 3 Code left panel: ~50% of problems have 0 success probability."""
    qs = tasks.gen_dataset("code", 4000, 0)
    frac0 = np.mean([q.lam == 0.0 for q in qs])
    assert 0.40 < frac0 < 0.60, frac0


def test_math_zero_mass_small():
    """Fig. 3 Math left panel: ~5% impossible, flat-ish otherwise."""
    qs = tasks.gen_dataset("math", 4000, 0)
    lam = np.asarray([q.lam for q in qs])
    assert np.mean(lam == 0.0) < 0.12
    # flat-ish: every coarse bin in (0,1] holds some nontrivial mass
    hist, _ = np.histogram(lam[lam > 0], bins=5, range=(0, 1))
    assert (hist > len(qs) * 0.02).all()


def test_lambda_bounds_and_determinism():
    qs = tasks.gen_dataset("code", 500, 3) + tasks.gen_dataset("math", 500, 3)
    for q in qs:
        assert 0.0 <= q.lam <= 1.0
    a = tasks.gen_dataset("code", 50, 42)
    b = tasks.gen_dataset("code", 50, 42)
    assert [q.text for q in a] == [q.text for q in b]
    assert [q.lam for q in a] == [q.lam for q in b]


def test_code_lambda_monotone_in_k():
    prev = 1.0
    for k in range(1, 9):
        lam = tasks.code_lambda(k, 0)
        assert lam < prev
        prev = lam
    assert tasks.code_lambda(9, 0) == 0.0


def test_math_lambda_monotone_in_length():
    lams = [tasks.math_lambda(L, 0) for L in range(1, 25)]
    assert all(a >= b for a, b in zip(lams, lams[1:]))


def test_answers_verify():
    qs = tasks.gen_dataset("code", 100, 1)
    for q in qs:
        vals = [int(t) for t in q.text.split()[1:]]
        assert q.answer == str(sum(vals) % 100)
    qs = tasks.gen_dataset("math", 100, 1)
    for q in qs:
        s = q.text.split(" ", 1)[1]
        assert q.answer == s[::-1]


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10_000), st.integers(1, 64))
def test_binary_outcomes_match_lambda(seed, k):
    qs = tasks.gen_dataset("code", 200, seed)
    out = tasks.sample_binary_outcomes(qs, k, seed + 1)
    assert out.shape == (200, k)
    lam = np.asarray([q.lam for q in qs])
    zero = lam == 0.0
    assert out[zero].sum() == 0  # impossible problems never succeed
    if k >= 32:
        err = np.abs(out.mean(axis=1) - lam)[~zero].mean()
        assert err < 0.12


def test_chat_params_ranges():
    qs = tasks.gen_dataset("chat", 2000, 0)
    mu = np.asarray([q.mu for q in qs])
    sg = np.asarray([q.sigma for q in qs])
    assert mu.min() > -1.0 and mu.max() < 3.0
    assert sg.min() >= 0.25 and sg.max() <= 0.85
    assert mu.std() > 0.05  # nontrivial predictable signal
    assert sg.std() > 0.1   # bimodal volatility (tranches experiment needs this)


def test_routing_weak_sometimes_wins():
    """Paper §4.2: the weak decoder sometimes beats the strong one."""
    qs = tasks.gen_dataset("chat", 2000, 0)
    pref = tasks.preference_prob(qs, 32, 1)
    assert (pref < 0.5).any() and (pref > 0.5).any()
    assert pref.mean() > 0.5  # strong wins on average


def test_vas_prefs_lower_entropy():
    """Fig. 5: VAS preference distribution has lower spread than model-size."""
    qs = tasks.gen_dataset("chat", 2000, 0)
    p_size = tasks.preference_prob(qs, 32, 1, vas=False)
    p_vas = tasks.preference_prob(qs, 32, 1, vas=True)
    assert p_vas.std() < p_size.std()


def test_corpus_format():
    lines = tasks.gen_corpus(200, 0)
    for ln in lines:
        assert " = " in ln
        assert ln.split()[0] in ("ADD", "REV", "CHAT")
