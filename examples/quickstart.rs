//! Quickstart: load the engine, predict difficulty for a handful of
//! queries, allocate a compute budget adaptively, generate + verify.
//!
//!   cargo run --release --offline --example quickstart
//!
//! Runs out of the box on the default native backend. To use the PJRT/XLA
//! path instead, build with `--features xla-runtime`, run `make artifacts`,
//! and set `backend: BackendKind::Xla` on the runtime config.

use thinkalloc::allocator::online::OnlineAllocator;
use thinkalloc::config::RuntimeConfig;
use thinkalloc::prng::Pcg64;
use thinkalloc::runtime::predictor::Predictor;
use thinkalloc::runtime::Engine;
use thinkalloc::serving::generator::{self, GenConfig};
use thinkalloc::serving::scheduler::compute_answer;

fn main() -> anyhow::Result<()> {
    // 1. load the engine (compiles the HLO artifacts once)
    let cfg = RuntimeConfig::default();
    let engine = Engine::load_all(&cfg)?;
    println!("engine up on {} ({:?} kernels)\n", engine.platform(), engine.kernel_mode());

    // 2. a small batch of code-domain queries of very different difficulty
    let queries = [
        "ADD 3 4",                         // trivial: one sample should do
        "ADD 12 93",                       // easy
        "ADD 12 93 7 55 21",               // mid: a few samples
        "ADD 81 3 66 24 9 17 40 2",        // hard but possible (k = 8)
        "ADD 9 8 7 6 5 4 3 2 1 11 22 33",  // k > 8 ⇒ impossible (λ = 0)
    ];

    // 3. predict difficulty (one fused encoder+probe call)
    let predictor = Predictor::new(&engine);
    let lam = predictor.predict_scalar(
        thinkalloc::runtime::predictor::ProbeKind::CodeLambda,
        &queries,
    )?;
    println!("predicted λ̂ (success probability per sample):");
    for (q, l) in queries.iter().zip(&lam) {
        println!("  {l:.3}  {q}");
    }

    // 4. allocate an average budget of 4 samples/query adaptively (eq. 5)
    let alloc = OnlineAllocator::new(16, 0)
        .allocate(&thinkalloc::allocator::online::Predictions::Lambdas(lam.clone()), 4.0);
    println!("\nadaptive allocation (B = 4/query, total = {}):", alloc.total_units);
    for (q, b) in queries.iter().zip(&alloc.budgets) {
        println!("  {b:>2} samples  {q}");
    }

    // 5. generate and verify
    let mut rng = Pcg64::new(7);
    let jobs = generator::jobs_for_allocation(&queries, &alloc.budgets);
    let samples = generator::generate(&engine, &jobs, &GenConfig::default(), &mut rng)?;
    let mut solved = vec![false; queries.len()];
    for s in &samples {
        if s.text.trim() == compute_answer(queries[s.query]) {
            solved[s.query] = true;
        }
    }
    println!("\nresults:");
    for (i, q) in queries.iter().enumerate() {
        let verdict = if solved[i] {
            "solved"
        } else if alloc.budgets[i] == 0 {
            "skipped (predicted impossible)"
        } else {
            "failed"
        };
        println!("  {verdict:<32} {q}");
    }
    Ok(())
}
