//! Headline demonstration: adaptive allocation vs uniform best-of-k at equal
//! compute, end to end — real predictor, real generation, real verification.
//!
//!   cargo run --release --offline --example adaptive_vs_uniform -- [n] [budget]
//!
//! Serves `n` code-domain queries (default 48) twice through the full
//! scheduler — once with the online adaptive policy, once uniform — and
//! reports solved counts and sample usage. The adaptive run should solve
//! more with the same number of samples (paper §4.1).

use std::sync::Arc;

use thinkalloc::config::{AllocPolicy, Config};
use thinkalloc::metrics::Registry;
use thinkalloc::prng::Pcg64;
use thinkalloc::runtime::Engine;
use thinkalloc::serving::scheduler::Scheduler;
use thinkalloc::serving::Request;
use thinkalloc::workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(48);
    let budget: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4.0);

    let qs = workload::gen_dataset("code", n, 42);
    let reqs: Vec<Request> = qs
        .iter()
        .enumerate()
        .map(|(i, q)| Request::new(i as u64, q.text.clone(), "code"))
        .collect();

    let mut results = Vec::new();
    for policy in [AllocPolicy::Uniform, AllocPolicy::Online] {
        let mut cfg = Config::default();
        cfg.allocator.policy = policy;
        cfg.allocator.budget_per_query = budget;
        cfg.allocator.b_max = 16;
        let metrics = Arc::new(Registry::default());
        let engine = Engine::load_all(&cfg.runtime)?;
        let scheduler = Scheduler::new(engine, cfg, metrics.clone());
        let mut rng = Pcg64::new(1234); // same sampling noise for both runs

        let t0 = std::time::Instant::now();
        let mut solved = 0usize;
        for chunk in reqs.chunks(64) {
            let responses =
                scheduler.serve_epoch(chunk, &mut rng, scheduler.effective_budget())?;
            solved += responses.iter().filter(|r| r.ok).count();
        }
        let wall = t0.elapsed().as_secs_f64();
        let units = metrics.counter("serving.units_allocated").get();
        println!(
            "{policy:?}: solved {solved}/{n} queries using {units} samples \
             ({wall:.1}s wall)"
        );
        results.push((policy, solved, units));
    }

    let (_, uni_solved, uni_units) = results[0];
    let (_, ada_solved, ada_units) = results[1];
    println!(
        "\nadaptive vs uniform at B={budget}: {ada_solved} vs {uni_solved} solved \
         ({ada_units} vs {uni_units} samples)"
    );
    if ada_solved >= uni_solved && ada_units <= uni_units {
        println!("⇒ adaptive matches/beats uniform at no extra compute ✓");
    }
    Ok(())
}
