//! End-to-end serving validation (DESIGN.md §7): start the full TCP stack,
//! replay a Poisson workload trace through real sockets *with arrival
//! pacing* (open-loop load, the standard serving-benchmark model), and
//! report latency percentiles, queue wait, throughput and quality vs the
//! allocation policy — optionally with the load-adaptive budget controller
//! steering the effective budget.
//!
//!   cargo run --release --offline --example serve_trace -- \
//!       [n] [policy] [budget] [rate_qps] [controller]
//!
//! `rate_qps` 0 (the default) submits the whole trace at once (closed-loop,
//! the historical behaviour); a positive rate generates Poisson arrivals at
//! that offered load and sleeps between submits. Passing `controller` as
//! the fifth argument enables the `[controller]` feedback loop so the
//! effective budget adapts to queue pressure.
//!
//! Everything is live: the configured backend (native by default; the
//! `make artifacts` TinyLM under `--features xla-runtime`) predicts
//! difficulty, the allocator splits the budget, the decode head generates
//! candidates, the synthetic verifier checks them.

use std::time::{Duration, Instant};

use thinkalloc::config::Config;
use thinkalloc::jsonio::Json;
use thinkalloc::metrics::Registry;
use thinkalloc::server::{Client, Server};
use thinkalloc::workload::trace::Trace;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(96);
    let policy = args.get(1).cloned().unwrap_or_else(|| "online".into());
    let budget: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(4.0);
    let rate: f64 = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(0.0);
    let controller = args.get(4).map(String::as_str) == Some("controller");

    let mut cfg = Config::default();
    cfg.server.addr = "127.0.0.1:0".into(); // ephemeral port
    cfg.server.batch_queries = 48;
    cfg.server.max_wait_ms = 40;
    cfg.allocator.policy = policy.parse()?;
    cfg.allocator.budget_per_query = budget;
    cfg.allocator.b_max = 16;
    if controller {
        cfg.controller.enabled = true;
        cfg.controller.target_queue_wait_ms = 50.0;
        cfg.controller.min_budget = 1.0;
        cfg.controller.max_budget = budget.max(1.0);
        cfg.controller.gain = 0.5;
        cfg.controller.ewma_window = 4;
    }

    let metrics = std::sync::Arc::new(Registry::default());
    let server = Server::new(cfg, metrics);
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = server.clone();
    let handle = std::thread::spawn(move || {
        srv.run(|addr| addr_tx.send(addr).unwrap()).unwrap();
    });
    let addr = addr_rx.recv()?;
    println!(
        "server ready on {addr} (policy {policy}, B={budget}, rate {}, controller {})",
        if rate > 0.0 { format!("{rate} q/s") } else { "closed-loop".into() },
        if controller { "on" } else { "off" },
    );

    // Poisson trace: binary-domain mix so responses are verifiable. A zero
    // rate degenerates to "submit everything now".
    let trace = Trace::poisson(n, if rate > 0.0 { rate } else { 1e9 }, (0.7, 0.3, 0.0), 777);
    let mut client = Client::connect(&addr)?;
    let t0 = Instant::now();
    for (i, e) in trace.entries.iter().enumerate() {
        if rate > 0.0 {
            let due = Duration::from_micros(e.at_us);
            let elapsed = t0.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
        }
        client.request(i as u64, &e.text, &e.domain)?;
    }
    let mut solved = 0usize;
    let mut latencies: Vec<f64> = Vec::with_capacity(n);
    let mut budgets_used = 0usize;
    for _ in 0..n {
        let resp = client.read_response()?;
        if resp.get("ok").and_then(Json::as_bool).unwrap_or(false) {
            solved += 1;
        }
        budgets_used += resp
            .get("budget")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as usize;
        latencies.push(
            resp.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0) / 1000.0,
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];

    println!("\n== serve_trace report ==");
    println!(
        "queries:        {n} ({})",
        if rate > 0.0 {
            format!("offered {:.1} q/s", trace.offered_rate())
        } else {
            "closed-loop".to_string()
        }
    );
    println!("solved:         {solved} ({:.1}%)", 100.0 * solved as f64 / n as f64);
    println!("samples used:   {budgets_used} (avg {:.2}/query)", budgets_used as f64 / n as f64);
    println!("throughput:     {:.1} queries/s", n as f64 / wall);
    println!("latency ms:     p50={:.0} p90={:.0} p99={:.0}", pct(0.5), pct(0.9), pct(0.99));

    let m = client.command("metrics")?;
    if let Some(h) = m.get("hist.serving.epoch_us") {
        println!("epoch time:     {}µs p50 (server-side)",
            h.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0) as u64);
    }
    if let Some(h) = m.get("hist.serving.queue_wait_us") {
        println!("queue wait:     {}µs p90 (server-side)",
            h.get("p90_us").and_then(Json::as_f64).unwrap_or(0.0) as u64);
    }
    if let Some(b) = m.get("gauge.serving.controller.budget").and_then(Json::as_f64) {
        let e = m
            .get("gauge.serving.controller.error")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        println!("controller:     effective budget {b:.2} (smoothed error {e:+.2})");
    }
    client.command("shutdown")?;
    let _ = handle.join();
    Ok(())
}
