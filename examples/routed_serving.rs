//! Weak/strong routing in the live serving path (paper §3.3, DESIGN.md §6):
//! a mixed-domain request stream is served twice — once with every query
//! taking the full adaptive best-of-k decode, once with `WeakStrongRoute`
//! sending only the predicted-preference top fraction through it and the
//! rest through a single cheap sample — and the quality/compute trade is
//! reported from the `serving.route.*` metrics.
//!
//!   cargo run --release --offline --example routed_serving -- [n] [strong_frac]

use std::sync::Arc;

use thinkalloc::config::{Config, ProcedureKind};
use thinkalloc::metrics::Registry;
use thinkalloc::prng::Pcg64;
use thinkalloc::runtime::Engine;
use thinkalloc::serving::scheduler::Scheduler;
use thinkalloc::serving::Request;
use thinkalloc::workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(192);
    let frac: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.4);

    let reqs: Vec<Request> = workload::gen_mixed_dataset(&["code", "math", "chat"], n, 1717)
        .into_iter()
        .enumerate()
        .map(|(i, q)| Request::new(i as u64, q.text, q.domain))
        .collect();

    let mut report = Vec::new();
    for procedure in [ProcedureKind::AdaptiveBestOfK, ProcedureKind::WeakStrongRoute] {
        let mut cfg = Config::default();
        cfg.allocator.budget_per_query = 4.0;
        cfg.allocator.b_max = 8;
        cfg.route.procedure = procedure;
        cfg.route.strong_fraction = frac;
        cfg.validate()?;

        let metrics = Arc::new(Registry::default());
        let engine = Engine::load_all(&cfg.runtime)?;
        let scheduler = Scheduler::new(engine, cfg, metrics.clone());
        let mut rng = Pcg64::new(99); // same sampling noise for both runs

        let t0 = std::time::Instant::now();
        let mut solved = 0usize;
        let mut reward_sum = 0.0f64;
        let mut chat_n = 0usize;
        for chunk in reqs.chunks(64) {
            for r in scheduler.serve_epoch(chunk, &mut rng, scheduler.effective_budget())? {
                if reqs[r.id as usize].domain == "chat" {
                    reward_sum += r.reward as f64;
                    chat_n += 1;
                } else if r.ok {
                    solved += 1;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let units = metrics.counter("serving.units_allocated").get();
        println!("== {} ==", procedure.name());
        println!("  solved (code/math): {solved}");
        println!("  mean chat reward:   {:.4}", reward_sum / chat_n.max(1) as f64);
        println!("  samples spent:      {units} ({:.2}/query)", units as f64 / n as f64);
        println!("  wall time:          {wall:.1}s");
        if procedure == ProcedureKind::WeakStrongRoute {
            let strong = metrics.counter("serving.route.strong").get();
            let weak = metrics.counter("serving.route.weak").get();
            println!(
                "  routed strong:      {strong}/{} (target {:.0}%, realized {:.1}%)",
                strong + weak,
                frac * 100.0,
                metrics.gauge("serving.route.strong_fraction").get() * 100.0
            );
            println!(
                "  arm latency p50:    strong {:.0}µs | weak {:.0}µs",
                metrics.histogram("serving.route.strong_us").percentile_us(0.5),
                metrics.histogram("serving.route.weak_us").percentile_us(0.5),
            );
        }
        report.push((procedure, solved, units));
    }

    let (_, full_solved, full_units) = report[0];
    let (_, routed_solved, routed_units) = report[1];
    println!(
        "\nrouting at {:.0}% strong: {routed_solved} solved with {routed_units} samples \
         vs {full_solved} with {full_units} all-strong \
         ({:.0}% of the compute)",
        frac * 100.0,
        100.0 * routed_units as f64 / full_units.max(1) as f64
    );
    Ok(())
}
