//! Routing demo (paper §4.2): use the learned preference predictor to route
//! chat queries between a weak and a strong decoder, sweeping the strong
//! fraction, vs the random baseline.
//!
//!   cargo run --release --offline --example routing_demo -- [n] [--vas]

use thinkalloc::baselines::random_routing;
use thinkalloc::prng::Pcg64;
use thinkalloc::router::{route_top_fraction, routing_cost, ThresholdRouter};
use thinkalloc::runtime::predictor::{Predictor, ProbeKind};
use thinkalloc::runtime::Engine;
use thinkalloc::simulator::{eval_routing_mask, RewardMatrix};
use thinkalloc::workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let vas = args.iter().any(|a| a == "--vas");
    let setting = if vas { "value-augmented sampling" } else { "Gemma-2b vs 7b analogue" };

    let engine = Engine::load_all(&Default::default())?;
    let predictor = Predictor::new(&engine);
    let qs = workload::gen_dataset("chat", n, 99);
    let texts: Vec<&str> = qs.iter().map(|q| q.text.as_str()).collect();
    let kind = if vas { ProbeKind::VasPreference } else { ProbeKind::RoutePreference };
    let pref = predictor.predict_scalar(kind, &texts)?;

    let k = 32;
    let (w, s) = workload::sample_routing_rewards(&qs, k, 3, vas);
    let weak = RewardMatrix::new(w, n, k);
    let strong = RewardMatrix::new(s, n, k);
    let weak_cost = if vas { 0.1 } else { 2.0 / 7.0 }; // VAS: 10× decoding cost

    println!("routing setting: {setting}");
    println!("{:<10} {:>10} {:>10} {:>12}", "strong %", "random", "adaptive", "rel. cost");
    let mut rng = Pcg64::new(5);
    for i in 0..=8 {
        let f = i as f64 / 8.0;
        let r = eval_routing_mask(&weak, &strong, &random_routing(n, f, &mut rng));
        let mask = route_top_fraction(&pref, f);
        let a = eval_routing_mask(&weak, &strong, &mask);
        let cost = routing_cost(&mask, weak_cost) / n as f64;
        println!("{:<10.0} {r:>10.4} {a:>10.4} {cost:>12.3}", f * 100.0);
    }

    // deployment-style threshold router calibrated at 50%
    let router = ThresholdRouter::fit(&pref, 0.5);
    let mask = router.route(&pref);
    let frac = mask.iter().filter(|&&m| m).count() as f64 / n as f64;
    println!(
        "\nthreshold router @50%: threshold={:.3}, actual strong fraction {:.1}%, \
         reward {:.4}",
        router.threshold,
        frac * 100.0,
        eval_routing_mask(&weak, &strong, &mask)
    );
    Ok(())
}
