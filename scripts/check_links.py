#!/usr/bin/env python3
"""Markdown link + anchor checker for the repo's documentation surface.

Usage: python3 scripts/check_links.py README.md rust/DESIGN.md docs/

Arguments are markdown files or directories (a directory is expanded to
every `*.md` under it, recursively — pointing CI at `docs/` keeps new
documents covered without editing the workflow).

Checks that every relative link target `[text](path)` in the given files
resolves to an existing file or directory, and that `#anchor` fragments —
in-page or into another markdown file — match a real heading in the target
document (GitHub slugification). http(s) and mailto links are skipped —
CI must not depend on external sites. Exits non-zero listing every broken
link.
"""

import functools
import re
import sys
from pathlib import Path

# [text](target) — excluding images' leading "!" matters not for existence
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# inline code spans: link-shaped text inside `...` (e.g. `m[i](j)`) is code,
# not a link — strip before matching so the hard CI gate can't false-fail
CODE_SPAN_RE = re.compile(r"`[^`]*`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: inline code/formatting markers dropped,
    lowercased, punctuation removed, spaces to hyphens."""
    text = heading.strip().lower()
    text = re.sub(r"[`*_]", "", text)
    # drop a trailing "{#custom-id}" if ever used
    text = re.sub(r"\{#[^}]*\}\s*$", "", text).strip()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def anchors_of(md_path: Path) -> set[str]:
    """All heading anchors a markdown file exposes (with GitHub's -1, -2
    suffixes for duplicate headings). Cached per file — a file with many
    inbound anchored links is parsed once."""
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_code = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check(md_path: Path) -> list[str]:
    errors = []
    text = md_path.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for target in LINK_RE.findall(CODE_SPAN_RE.sub("`", line)):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            if path_part:
                resolved = (md_path.parent / path_part).resolve()
                if not resolved.exists():
                    errors.append(f"{md_path}:{lineno}: broken link `{target}`")
                    continue
            else:
                resolved = md_path.resolve()  # pure in-page anchor
            if anchor and resolved.suffix == ".md" and resolved.is_file():
                if anchor not in anchors_of(resolved):
                    errors.append(
                        f"{md_path}:{lineno}: broken anchor `{target}` "
                        f"(no heading `#{anchor}` in {resolved.name})"
                    )
    return errors


def expand(arg: str) -> list[Path]:
    p = Path(arg)
    if p.is_dir():
        return sorted(p.rglob("*.md"))
    return [p]


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__.strip())
        return 2
    all_errors = []
    files: list[Path] = []
    for arg in sys.argv[1:]:
        expanded = expand(arg)
        if not expanded or not all(p.exists() for p in expanded):
            all_errors.append(f"{arg}: file not found")
            continue
        files.extend(expanded)
    for p in files:
        all_errors.extend(check(p))
    if all_errors:
        print("\n".join(all_errors))
        return 1
    print(f"checked {len(files)} files: all relative links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
