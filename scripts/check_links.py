#!/usr/bin/env python3
"""Markdown link checker for the repo's documentation surface.

Usage: python3 scripts/check_links.py README.md rust/DESIGN.md docs/PROTOCOL.md

Checks that every relative link target `[text](path)` in the given files
resolves to an existing file or directory (anchors are stripped; http(s)
and mailto links are skipped — CI must not depend on external sites).
Exits non-zero listing every broken link.
"""

import re
import sys
from pathlib import Path

# [text](target) — excluding images' leading "!" matters not for existence
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# inline code spans: link-shaped text inside `...` (e.g. `m[i](j)`) is code,
# not a link — strip before matching so the hard CI gate can't false-fail
CODE_SPAN_RE = re.compile(r"`[^`]*`")


def check(md_path: Path) -> list[str]:
    errors = []
    text = md_path.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for target in LINK_RE.findall(CODE_SPAN_RE.sub("`", line)):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:  # pure in-page anchor
                continue
            resolved = (md_path.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{md_path}:{lineno}: broken link `{target}`")
    return errors


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__.strip())
        return 2
    all_errors = []
    for arg in sys.argv[1:]:
        p = Path(arg)
        if not p.exists():
            all_errors.append(f"{arg}: file not found")
            continue
        all_errors.extend(check(p))
    if all_errors:
        print("\n".join(all_errors))
        return 1
    print(f"checked {len(sys.argv) - 1} files: all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
