#!/usr/bin/env python3
"""Compare two bench_serving --json summaries and fail on regressions.

Usage: perf_compare.py BASELINE.json CANDIDATE.json [--tolerance 0.15]

Gated metrics (the serving hot path's load-bearing numbers):
  higher is better: decode steps/s, epoch & pool & front-door & fleet
                    queries/s
  lower is better:  p95 queue wait (controller on, and under saturation),
                    fleet replica-loss recovery p95, fleet per-request
                    placement overhead, fleet deadline-overshoot p95 (how
                    far past a client deadline the structured failure line
                    lands — the dispatch-sweep granularity bound)

A candidate worse than baseline by more than the tolerance on any present
metric exits nonzero and says which. Metrics missing from either file are
skipped with a note — bench sections come and go, and a perf gate must not
turn into a schema gate. Values <= 0 are skipped for the same reason
(smoke runs can legitimately produce empty histograms).

With --hard-metrics, only the HARD subset (decode steps/s, the two p95
queue waits, and the fleet tier's recovery p95, placement overhead, and
deadline-overshoot p95 — the numbers the serving claims actually rest on)
can fail the run;
everything else is compared and printed as advisory. That is the
CI mode: noisy shared runners make the throughput-style metrics flap, but
a real decode or queue-wait regression should block the merge.
"""

import argparse
import json
import sys

# (top-level key in the bench summary, field inside it, direction)
METRICS = [
    ("decode.continuous", "steps_per_s", "higher"),
    ("epoch.online", "queries_per_s", "higher"),
    ("pool.workers_4", "queries_per_s", "higher"),
    ("many_conn.event", "queries_per_s", "higher"),
    ("many_socket.event", "queries_per_s", "higher"),
    ("fleet.replay", "queries_per_s", "higher"),
    ("sessions.warm", "warm_turn_slot_steps", "lower"),
    ("controller.on", "queue_wait_p95_us", "lower"),
    ("saturation", "queue_wait_p95_us", "lower"),
    ("fleet.recovery", "recovery_p95_ms", "lower"),
    ("fleet.placement", "overhead_us_per_req", "lower"),
    ("fleet.deadline", "overshoot_p95_ms", "lower"),
]

# the metrics that hard-gate CI under --hard-metrics (see module docstring)
HARD = {
    "decode.continuous.steps_per_s",
    "controller.on.queue_wait_p95_us",
    "saturation.queue_wait_p95_us",
    "fleet.recovery.recovery_p95_ms",
    "fleet.placement.overhead_us_per_req",
    "fleet.deadline.overshoot_p95_ms",
}


def load(path):
    with open(path) as f:
        return json.load(f)


def pick(doc, top, field):
    sec = doc.get(top)
    if not isinstance(sec, dict):
        return None
    v = sec.get(field)
    return v if isinstance(v, (int, float)) else None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed fractional regression (default 0.15)")
    ap.add_argument("--hard-metrics", action="store_true",
                    help="only the HARD metric subset can fail the run; "
                         "the rest are compared as advisory")
    args = ap.parse_args()

    base = load(args.baseline)
    cand = load(args.candidate)
    tol = args.tolerance

    regressions = []
    for top, field, direction in METRICS:
        name = f"{top}.{field}"
        gating = not args.hard_metrics or name in HARD
        b, c = pick(base, top, field), pick(cand, top, field)
        if b is None or c is None or b <= 0 or c <= 0:
            print(f"  skip {name}: baseline={b} candidate={c}")
            continue
        # signed fractional regression: positive = candidate is worse
        if direction == "higher":
            reg = (b - c) / b
        else:
            reg = (c - b) / b
        if reg > tol:
            verdict = "REGRESSION" if gating else "advisory-regression"
        else:
            verdict = "ok"
        print(f"  {verdict:>10} {name}: baseline {b:.1f} -> candidate {c:.1f} "
              f"({reg:+.1%} regression, tolerance {tol:.0%})")
        if reg > tol and gating:
            regressions.append((name, reg))

    if regressions:
        worst = max(regressions, key=lambda r: r[1])
        print(f"\nFAIL: {len(regressions)} metric(s) regressed beyond "
              f"{tol:.0%}; worst is {worst[0]} at {worst[1]:+.1%}")
        return 1
    print("\nOK: no gated metric regressed beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
